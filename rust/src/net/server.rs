//! `net::server` — the socket front end over a [`ServeHandle`].
//!
//! A `std::net::TcpListener` accept loop feeds a single-threaded,
//! non-blocking, poll-driven connection reactor (no async runtime — the
//! offline build carries no extra crates). The reactor:
//!
//! * decodes [`proto`](super::proto) frames incrementally off each
//!   socket and submits requests through [`ServeHandle::try_submit_class`]
//!   — the *non-blocking* admission path, so one saturated queue never
//!   stalls the reactor;
//! * applies **per-connection backpressure**: at most
//!   [`NetConfig::inflight_window`] requests per socket are in flight at
//!   once, and a connection with more than [`WRITE_HIGH_WATER`] unsent
//!   reply bytes stops being decoded until the client drains it;
//! * **sheds load** with typed `RetryAfter` frames (carrying the current
//!   flush-window as the retry hint) whenever the admission queue is
//!   saturated — the request was *not* accepted and the client may retry;
//! * answers metrics scrapes on the same listener, as a binary
//!   `MetricsRequest` frame or a plain-text `GET` (HTTP/1.0) response;
//! * **drains gracefully** on [`NetServer::shutdown`]: stop accepting,
//!   stop reading, flush every in-flight (= admitted) request's reply,
//!   close. An accepted request is never dropped by the drain; buffered
//!   bytes that never reached admission are simply discarded.
//!
//! Replies are delivered **in submission order per connection** (FIFO):
//! the reactor polls only the oldest pending reply of each socket, so a
//! client that pipelines requests reads answers in the order it sent
//! them, ids matching one-to-one.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::runtime::{Result, RuntimeError};
use crate::serve::{Pending, ServeHandle, ServeReport};

use super::metrics::{self, NetStats};
use super::proto::{self, Frame, ProtoError};

/// Stop decoding a connection while it holds this many unsent bytes:
/// a client that stops reading stops being served, instead of growing
/// the reactor's buffers without bound.
pub const WRITE_HIGH_WATER: usize = 1 << 20;

/// Drop an HTTP connection whose request line never completes within
/// this many buffered bytes.
const HTTP_REQUEST_CAP: usize = 8 * 1024;

/// Wire-latency samples kept for the percentile lines (ring buffer).
const LATENCY_WINDOW: usize = 4096;

/// Reactor tuning for [`NetServer::spawn`].
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Max requests in flight per connection before the reactor stops
    /// decoding that socket (per-connection backpressure).
    pub inflight_window: usize,
    /// How long the reactor parks when a poll pass makes no progress.
    pub idle_park: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig { inflight_window: 32, idle_park: Duration::from_micros(500) }
    }
}

impl NetConfig {
    /// Set the per-connection in-flight window (clamped to >= 1).
    pub fn inflight_window(mut self, window: usize) -> Self {
        self.inflight_window = window.max(1);
        self
    }
}

/// Shutdown report: reactor counters plus the serve pipeline's report.
#[derive(Debug, Clone)]
pub struct NetReport {
    /// Final reactor counters.
    pub net: NetStats,
    /// The drained serve pipeline's report.
    pub serve: ServeReport,
}

struct Shared {
    draining: AtomicBool,
    /// Raised by a client `Drain` admin frame: the owning driver polls
    /// [`NetServer::drain_requested`] (or shares
    /// [`NetServer::drain_flag`] with a rollout loop, which pauses
    /// promotion) and then calls [`NetServer::shutdown`] — the std-only
    /// replacement for SIGTERM plumbing.
    drain_requested: Arc<AtomicBool>,
    stats: Mutex<NetStats>,
    latencies: Mutex<LatencyRing>,
}

/// Fixed-capacity ring of recent wire latencies (decode → reply write).
struct LatencyRing {
    samples: Vec<Duration>,
    next: usize,
}

impl LatencyRing {
    fn push(&mut self, d: Duration) {
        if self.samples.len() < LATENCY_WINDOW {
            self.samples.push(d);
        } else {
            self.samples[self.next % LATENCY_WINDOW] = d;
        }
        self.next = (self.next + 1) % LATENCY_WINDOW;
    }
}

/// A running socket front end. Dropping without [`NetServer::shutdown`]
/// tears the reactor down (drain, then join) but discards the report.
pub struct NetServer {
    shared: Arc<Shared>,
    reactor: Option<JoinHandle<()>>,
    handle: ServeHandle,
    local_addr: SocketAddr,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"`) and spawn the reactor over a
    /// clone of `handle`.
    pub fn bind(handle: ServeHandle, addr: &str, config: NetConfig) -> Result<NetServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| RuntimeError::Io(format!("net: bind {addr}: {e}")))?;
        NetServer::spawn(handle, listener, config)
    }

    /// Spawn the reactor thread over an already-bound listener.
    pub fn spawn(
        handle: ServeHandle,
        listener: TcpListener,
        config: NetConfig,
    ) -> Result<NetServer> {
        let local_addr = listener
            .local_addr()
            .map_err(|e| RuntimeError::Io(format!("net: local_addr: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| RuntimeError::Io(format!("net: set_nonblocking: {e}")))?;
        let shared = Arc::new(Shared {
            draining: AtomicBool::new(false),
            drain_requested: Arc::new(AtomicBool::new(false)),
            stats: Mutex::new(NetStats::default()),
            latencies: Mutex::new(LatencyRing { samples: Vec::new(), next: 0 }),
        });
        let reactor = {
            let shared = shared.clone();
            let handle = handle.clone();
            thread::Builder::new()
                .name("anode-net".into())
                .spawn(move || {
                    Reactor { listener, handle, shared, config, conns: Vec::new() }.run()
                })
                .map_err(|e| RuntimeError::Io(format!("net: reactor spawn failed: {e}")))?
        };
        Ok(NetServer { shared, reactor: Some(reactor), handle, local_addr })
    }

    /// The bound address (port resolved when binding `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The serve pipeline behind this listener (for in-process submits,
    /// hot swaps, or stats alongside the socket traffic).
    pub fn handle(&self) -> &ServeHandle {
        &self.handle
    }

    /// Snapshot of the reactor counters.
    pub fn stats(&self) -> NetStats {
        self.shared.stats.lock().expect("net stats lock").clone()
    }

    /// Has a client asked for a graceful drain (a `Drain` admin frame)?
    /// The reactor only *records* the request — acting on it (calling
    /// [`NetServer::shutdown`]) stays with the driver that owns the
    /// server, so the drain composes with whatever else the driver is
    /// coordinating (e.g. pausing a rollout promotion loop first).
    pub fn drain_requested(&self) -> bool {
        self.shared.drain_requested.load(Ordering::SeqCst)
    }

    /// The drain-request flag itself, for wiring into other loops (the
    /// rollout orchestrator's `pause_on` takes exactly this): the flag
    /// flips to `true` when a `Drain` frame arrives and is never reset.
    pub fn drain_flag(&self) -> Arc<AtomicBool> {
        self.shared.drain_requested.clone()
    }

    /// Render the metrics text exactly as a scrape would see it.
    pub fn metrics_text(&self) -> String {
        render_metrics(&self.handle, &self.shared)
    }

    /// Graceful drain: stop accepting and reading (bytes short of
    /// admission are discarded), shut the serve pipeline down — which
    /// flushes every *admitted* request's reply regardless of how far
    /// its deadline window is — flush those replies down the sockets,
    /// close, join the reactor, and return both reports.
    pub fn shutdown(mut self) -> Result<NetReport> {
        let (net, serve) = self.teardown();
        let serve = serve.expect("live reactor on first shutdown")?;
        Ok(NetReport { net, serve })
    }

    fn teardown(&mut self) -> (NetStats, Option<Result<ServeReport>>) {
        self.shared.draining.store(true, Ordering::SeqCst);
        // Drain the serve pipeline *before* joining the reactor: the
        // reactor's drain waits on admitted replies, and only the serve
        // drain guarantees those flush ahead of their deadline windows.
        let serve = self.reactor.as_ref().map(|_| self.handle.shutdown());
        if let Some(t) = self.reactor.take() {
            if t.join().is_err() {
                // The reactor never unwinds by design; surface it loudly
                // on the shutdown path rather than swallowing it.
                panic!("net: reactor thread panicked");
            }
        }
        (self.stats(), serve)
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        if self.reactor.is_some() && !thread::panicking() {
            let _ = self.teardown();
        }
    }
}

fn render_metrics(handle: &ServeHandle, shared: &Shared) -> String {
    let serve = handle.stats();
    let compile = handle.compile_stats();
    let net = shared.stats.lock().expect("net stats lock").clone();
    let mut lat = shared.latencies.lock().expect("net latency lock").samples.clone();
    metrics::render(&serve, &net, &mut lat, compile.as_ref())
}

/// One response slot in a connection's FIFO: either still waiting on the
/// serve pipeline, or already answered (sheds, metrics) and queued so
/// *every* response leaves in request order.
struct Inflight {
    id: u64,
    started: Instant,
    state: InflightState,
}

enum InflightState {
    /// Admitted into the serve pipeline; reply pending.
    Waiting(Pending),
    /// Answered at decode time (RetryAfter, MetricsReply); held in the
    /// FIFO so it cannot overtake an earlier request's reply.
    Ready(Frame),
}

/// Per-connection reactor state.
struct Conn {
    stream: TcpStream,
    read_buf: Vec<u8>,
    write_buf: Vec<u8>,
    inflight: VecDeque<Inflight>,
    /// Stop reading; close once `inflight` and `write_buf` drain.
    closing: bool,
    /// Hard-dead (io error): discard without flushing.
    dead: bool,
}

impl Conn {
    fn finished(&self) -> bool {
        self.dead || (self.closing && self.inflight.is_empty() && self.write_buf.is_empty())
    }
}

struct Reactor {
    listener: TcpListener,
    handle: ServeHandle,
    shared: Arc<Shared>,
    config: NetConfig,
    conns: Vec<Conn>,
}

impl Reactor {
    fn run(mut self) {
        loop {
            let draining = self.shared.draining.load(Ordering::SeqCst);
            let mut progress = false;
            if !draining {
                progress |= self.accept();
            }
            for i in 0..self.conns.len() {
                progress |= self.pump(i, draining);
            }
            let before = self.conns.len();
            self.conns.retain(|c| !c.finished());
            if self.conns.len() != before {
                let mut s = self.shared.stats.lock().expect("net stats lock");
                s.open_connections = self.conns.len() as u64;
            }
            let idle = |c: &Conn| c.inflight.is_empty() && c.write_buf.is_empty();
            if draining && self.conns.iter().all(idle) {
                // Every admitted request has been answered and flushed.
                return;
            }
            if !progress {
                thread::park_timeout(self.config.idle_park);
            }
        }
    }

    /// Accept until the listener would block. Returns whether anything
    /// was accepted.
    fn accept(&mut self) -> bool {
        let mut any = false;
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    self.conns.push(Conn {
                        stream,
                        read_buf: Vec::new(),
                        write_buf: Vec::new(),
                        inflight: VecDeque::new(),
                        closing: false,
                        dead: false,
                    });
                    let mut s = self.shared.stats.lock().expect("net stats lock");
                    s.connections += 1;
                    s.open_connections = self.conns.len() as u64;
                    any = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return any,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return any,
            }
        }
    }

    /// One poll pass over connection `i`: read, decode/submit, poll
    /// replies, write. Returns whether the pass made progress.
    fn pump(&mut self, i: usize, draining: bool) -> bool {
        let mut progress = false;
        progress |= self.read(i, draining);
        if !draining {
            // Bytes buffered but not yet admitted are discarded by the
            // drain — decoding them now would submit into a pipeline
            // that is already shutting down.
            progress |= self.decode(i);
        }
        progress |= self.poll_replies(i);
        progress |= self.write(i);
        progress
    }

    fn read(&mut self, i: usize, draining: bool) -> bool {
        let conn = &mut self.conns[i];
        if conn.dead || conn.closing || draining {
            // The drain stops reading: bytes short of admission are
            // discarded, admitted requests still get their replies.
            return false;
        }
        let mut buf = [0u8; 8192];
        let mut any = false;
        loop {
            match conn.stream.read(&mut buf) {
                Ok(0) => {
                    conn.closing = true;
                    return any;
                }
                Ok(n) => {
                    conn.read_buf.extend_from_slice(&buf[..n]);
                    any = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return any,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    conn.dead = true;
                    return any;
                }
            }
        }
    }

    /// Decode as many frames as backpressure allows and act on them.
    fn decode(&mut self, i: usize) -> bool {
        if self.conns[i].dead || self.conns[i].read_buf.is_empty() {
            return false;
        }
        // HTTP scrape path: same listener, one-shot text response.
        if proto::looks_like_http(&self.conns[i].read_buf) {
            return self.serve_http(i);
        }
        let mut consumed = 0usize;
        let mut progress = false;
        loop {
            let conn = &self.conns[i];
            if conn.closing
                || conn.inflight.len() >= self.config.inflight_window
                || conn.write_buf.len() >= WRITE_HIGH_WATER
            {
                break;
            }
            match proto::decode(&conn.read_buf[consumed..]) {
                Ok(None) => break,
                Ok(Some((frame, n))) => {
                    consumed += n;
                    progress = true;
                    self.on_frame(i, frame);
                }
                Err(e) => {
                    consumed = self.conns[i].read_buf.len();
                    progress = true;
                    self.on_protocol_error(i, e);
                    break;
                }
            }
        }
        if consumed > 0 {
            self.conns[i].read_buf.drain(..consumed);
        }
        progress
    }

    fn on_frame(&mut self, i: usize, frame: Frame) {
        {
            let mut s = self.shared.stats.lock().expect("net stats lock");
            s.frames_in += 1;
        }
        match frame {
            Frame::Request { id, class, image } => {
                let started = Instant::now();
                let state = match self.handle.try_submit_class(&image, class) {
                    Ok(Some(pending)) => InflightState::Waiting(pending),
                    Ok(None) => {
                        // Saturated admission queue: shed with the current
                        // flush window as the retry hint — by then the
                        // batcher has had a full window to make room.
                        let hint = self.handle.stats().current_max_delay;
                        self.shared.stats.lock().expect("net stats lock").shed += 1;
                        InflightState::Ready(Frame::retry_after(id, hint))
                    }
                    Err(e) => {
                        self.shared.stats.lock().expect("net stats lock").errors += 1;
                        InflightState::Ready(Frame::Error { id, message: e.to_string() })
                    }
                };
                self.conns[i].inflight.push_back(Inflight { id, started, state });
            }
            Frame::MetricsRequest { id } => {
                let text = render_metrics(&self.handle, &self.shared);
                self.conns[i].inflight.push_back(Inflight {
                    id,
                    started: Instant::now(),
                    state: InflightState::Ready(Frame::MetricsReply { id, text }),
                });
                self.shared.stats.lock().expect("net stats lock").metrics_requests += 1;
            }
            Frame::Drain { id } => {
                // Record the request and echo the frame as the ack; the
                // actual shutdown belongs to the driver that owns the
                // server (so it can pause rollout promotion first). The
                // ack rides the FIFO like any other response, so replies
                // already in flight still leave in order.
                self.shared.drain_requested.store(true, Ordering::SeqCst);
                self.conns[i].inflight.push_back(Inflight {
                    id,
                    started: Instant::now(),
                    state: InflightState::Ready(Frame::Drain { id }),
                });
                self.shared.stats.lock().expect("net stats lock").drain_requests += 1;
            }
            // Server-to-client frames arriving at the server are a
            // protocol violation, same as garbage bytes.
            Frame::Reply { .. }
            | Frame::Error { .. }
            | Frame::RetryAfter { .. }
            | Frame::MetricsReply { .. } => {
                self.on_protocol_error(i, ProtoError::Malformed("client sent a server-only frame"));
            }
        }
    }

    /// A malformed stream gets one explanatory `Error` frame (id 0 — no
    /// request id is trustworthy at this point), then the connection
    /// stops being read and closes after its admitted replies flush.
    fn on_protocol_error(&mut self, i: usize, e: ProtoError) {
        self.send(i, &Frame::Error { id: 0, message: e.to_string() });
        self.conns[i].closing = true;
        self.shared.stats.lock().expect("net stats lock").protocol_errors += 1;
    }

    /// Serve `GET <path> HTTP/1.x` once the request head is complete.
    fn serve_http(&mut self, i: usize) -> bool {
        let head_complete = {
            let buf = &self.conns[i].read_buf;
            buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.windows(2).any(|w| w == b"\n\n")
        };
        if !head_complete {
            if self.conns[i].read_buf.len() > HTTP_REQUEST_CAP {
                self.conns[i].dead = true;
                return true;
            }
            return false;
        }
        let text = render_metrics(&self.handle, &self.shared);
        let conn = &mut self.conns[i];
        conn.read_buf.clear();
        conn.write_buf.extend_from_slice(&metrics::http_response(&text));
        conn.closing = true;
        let mut s = self.shared.stats.lock().expect("net stats lock");
        s.metrics_requests += 1;
        true
    }

    /// Poll each connection's *oldest* response slot only: every
    /// response (reply, error, shed, metrics) leaves strictly in request
    /// order per connection.
    fn poll_replies(&mut self, i: usize) -> bool {
        let mut progress = false;
        loop {
            let conn = &mut self.conns[i];
            if conn.dead || conn.write_buf.len() >= WRITE_HIGH_WATER {
                return progress;
            }
            let Some(front) = conn.inflight.front() else { return progress };
            let frame = match &front.state {
                InflightState::Ready(frame) => frame.clone(),
                InflightState::Waiting(pending) => match pending.wait_timeout(Duration::ZERO) {
                    Ok(None) => return progress,
                    Ok(Some(reply)) => Frame::from_reply(front.id, &reply),
                    Err(e) => Frame::Error { id: front.id, message: e.to_string() },
                },
            };
            let done = conn.inflight.pop_front().expect("front exists");
            let was_waiting = matches!(done.state, InflightState::Waiting(_));
            let is_reply = matches!(frame, Frame::Reply { .. });
            self.send(i, &frame);
            let mut s = self.shared.stats.lock().expect("net stats lock");
            if is_reply {
                s.replies += 1;
                drop(s);
                let mut ring = self.shared.latencies.lock().expect("net latency lock");
                ring.push(done.started.elapsed());
            } else if was_waiting {
                // An admitted request that came back as an error.
                s.errors += 1;
            }
            progress = true;
        }
    }

    fn send(&mut self, i: usize, frame: &Frame) {
        frame.encode(&mut self.conns[i].write_buf);
    }

    fn write(&mut self, i: usize) -> bool {
        let conn = &mut self.conns[i];
        if conn.dead || conn.write_buf.is_empty() {
            return false;
        }
        let mut written = 0usize;
        loop {
            match conn.stream.write(&conn.write_buf[written..]) {
                Ok(0) => {
                    conn.dead = true;
                    break;
                }
                Ok(n) => {
                    written += n;
                    if written == conn.write_buf.len() {
                        break;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    conn.dead = true;
                    break;
                }
            }
        }
        if written > 0 {
            conn.write_buf.drain(..written);
        }
        written > 0
    }
}
