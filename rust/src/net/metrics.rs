//! `net::metrics` — the scrapeable metrics text.
//!
//! One render path serves both transports: a binary `MetricsRequest`
//! frame gets the text back in a `MetricsReply`, and a plain
//! `GET /metrics HTTP/1.0` on the same listener gets it as an HTTP
//! response (so `curl` and the CI scraper need no protocol client).
//!
//! The format is the Prometheus text convention — `name value` lines,
//! `{label="v"}` for per-device series — because every line-oriented
//! tool can parse it and CI turns it into `BENCH_net.json` fields.

use crate::compile::CompileStatsSnapshot;
use crate::serve::ServeStats;
use crate::util::bench::LatencyPercentiles;
use std::fmt::Write as _;
use std::time::Duration;

/// Live counters owned by the connection reactor, folded into the
/// metrics text next to the serve-layer [`ServeStats`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct NetStats {
    /// Connections accepted over the listener's lifetime.
    pub connections: u64,
    /// Connections currently open.
    pub open_connections: u64,
    /// Frames decoded off sockets (requests + metrics requests).
    pub frames_in: u64,
    /// Reply frames written (successful classifications).
    pub replies: u64,
    /// Error frames written.
    pub errors: u64,
    /// RetryAfter frames written — requests shed at the wire because the
    /// admission queue was saturated.
    pub shed: u64,
    /// Metrics scrapes served (binary frames + HTTP requests).
    pub metrics_requests: u64,
    /// Connections dropped for protocol violations (bad magic/version/
    /// frame type, malformed payload).
    pub protocol_errors: u64,
    /// `Drain` admin frames received (each raises the server's
    /// drain-request flag and is echoed back as the acknowledgement).
    pub drain_requests: u64,
}

/// Render the metrics text: serve-layer stats, reactor counters, the
/// wire-latency percentiles over the recent window (`latencies` is
/// drained percentile input, micros from frame decode to reply write),
/// and — when the pipeline executes through the compiled backend —
/// the compile-plan counters summed across device runners.
pub fn render(
    serve: &ServeStats,
    net: &NetStats,
    latencies: &mut [Duration],
    compile: Option<&CompileStatsSnapshot>,
) -> String {
    let wire = LatencyPercentiles::from_unsorted(latencies);
    let mut out = String::with_capacity(1024);
    let mut line = |name: &str, value: u64| {
        let _ = writeln!(out, "anode_{name} {value}");
    };
    line("submitted_total", serve.submitted);
    line("submitted_interactive_total", serve.submitted_interactive);
    line("submitted_batch_total", serve.submitted_batch);
    line("shed_total", serve.rejected);
    line("completed_total", serve.completed);
    line("batches_total", serve.batches);
    line("full_flushes_total", serve.full_flushes);
    line("deadline_flushes_total", serve.deadline_flushes);
    line("drain_flushes_total", serve.drain_flushes);
    line("queue_depth", serve.queue_depth as u64);
    line("max_delay_us", duration_us(serve.current_max_delay));
    line("adaptive_delay", u64::from(serve.adaptive_delay));
    line("memory_traffic_bytes", serve.memory_traffic);
    line("memory_worker_peak_bytes", serve.memory_worker_peak);
    line("rollout_candidates_total", serve.rollout_candidates);
    line("rollout_promotions_total", serve.rollout_promotions);
    line("rollout_rollbacks_total", serve.rollout_rollbacks);
    line("rollout_swap_p99_us", serve.rollout_swap_p99_us);
    line("closed", u64::from(serve.closed));
    line("net_connections_total", net.connections);
    line("net_open_connections", net.open_connections);
    line("net_frames_in_total", net.frames_in);
    line("net_replies_total", net.replies);
    line("net_errors_total", net.errors);
    line("net_shed_total", net.shed);
    line("net_metrics_requests_total", net.metrics_requests);
    line("net_protocol_errors_total", net.protocol_errors);
    line("net_drain_requests_total", net.drain_requests);
    line("net_latency_samples", latencies.len() as u64);
    line("net_latency_p50_us", duration_us(wire.p50));
    line("net_latency_p95_us", duration_us(wire.p95));
    line("net_latency_p99_us", duration_us(wire.p99));
    if let Some(c) = compile {
        line("compile_plans_cached", c.plans_cached);
        line("compile_fused_ops", c.fused_ops);
        line("compile_folded_consts", c.folded_consts);
        line("compile_arena_bytes", c.arena_bytes);
        line("compile_arena_allocs_total", c.arena_allocs);
        line("compile_arena_reuses_total", c.arena_reuses);
        line("compile_train_trajectory_bytes", c.trajectory_bytes);
        line("compile_train_recompute_segments", c.train_recompute_segments);
        line("compile_train_interp_nodes", c.train_interp_nodes);
        line("compile_train_arena_allocs_total", c.train_arena_allocs);
        line("compile_train_arena_reuses_total", c.train_arena_reuses);
    }
    for (device, load) in serve.device_loads.iter().enumerate() {
        let _ = writeln!(out, "anode_device_load{{device=\"{device}\"}} {load}");
    }
    out
}

/// Wrap the metrics text as a complete HTTP/1.0 response (the listener
/// speaks HTTP only for scrapes; `Connection: close` keeps the reactor's
/// HTTP handling one-shot).
pub fn http_response(body: &str) -> Vec<u8> {
    format!(
        "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

fn duration_us(d: Duration) -> u64 {
    d.as_micros().min(u64::MAX as u128) as u64
}

/// Pull one `anode_<name> <value>` line out of a rendered metrics text
/// (the CI scraper and tests share this instead of regexing).
pub fn scrape_value(text: &str, name: &str) -> Option<u64> {
    let needle = format!("anode_{name} ");
    text.lines().find_map(|l| l.strip_prefix(&needle).and_then(|v| v.trim().parse().ok()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> ServeStats {
        ServeStats {
            submitted: 10,
            submitted_interactive: 7,
            submitted_batch: 3,
            rejected: 2,
            completed: 9,
            batches: 4,
            full_flushes: 2,
            deadline_flushes: 1,
            drain_flushes: 1,
            queue_depth: 1,
            device_loads: vec![1, 0],
            current_max_delay: Duration::from_millis(3),
            adaptive_delay: true,
            memory_traffic: 4096,
            memory_worker_peak: 1024,
            rollout_candidates: 6,
            rollout_promotions: 4,
            rollout_rollbacks: 1,
            rollout_swap_p99_us: 750,
            closed: false,
        }
    }

    #[test]
    fn render_emits_scrapeable_lines() {
        let net = NetStats { connections: 5, shed: 2, ..NetStats::default() };
        let mut lat = vec![Duration::from_micros(100), Duration::from_micros(300)];
        let text = render(&stats(), &net, &mut lat, None);
        assert_eq!(scrape_value(&text, "submitted_total"), Some(10));
        assert_eq!(scrape_value(&text, "submitted_batch_total"), Some(3));
        assert_eq!(scrape_value(&text, "shed_total"), Some(2));
        assert_eq!(scrape_value(&text, "max_delay_us"), Some(3000));
        assert_eq!(scrape_value(&text, "adaptive_delay"), Some(1));
        assert_eq!(scrape_value(&text, "net_connections_total"), Some(5));
        assert_eq!(scrape_value(&text, "net_latency_samples"), Some(2));
        assert_eq!(scrape_value(&text, "net_latency_p50_us"), Some(300));
        assert_eq!(scrape_value(&text, "rollout_candidates_total"), Some(6));
        assert_eq!(scrape_value(&text, "rollout_promotions_total"), Some(4));
        assert_eq!(scrape_value(&text, "rollout_rollbacks_total"), Some(1));
        assert_eq!(scrape_value(&text, "rollout_swap_p99_us"), Some(750));
        assert_eq!(scrape_value(&text, "net_drain_requests_total"), Some(0));
        assert!(text.contains("anode_device_load{device=\"1\"} 0\n"), "{text}");
        // Pipelines off the compiled backend export no compile series.
        assert_eq!(scrape_value(&text, "compile_plans_cached"), None);
    }

    #[test]
    fn render_exports_compile_counters_when_present() {
        let compile = CompileStatsSnapshot {
            plans_cached: 12,
            fused_ops: 24,
            folded_consts: 24,
            arena_bytes: 8192,
            arena_allocs: 2,
            arena_reuses: 98,
            trajectory_bytes: 4096,
            train_recompute_segments: 6,
            train_interp_nodes: 5,
            train_arena_allocs: 3,
            train_arena_reuses: 97,
        };
        let text = render(&stats(), &NetStats::default(), &mut [], Some(&compile));
        assert_eq!(scrape_value(&text, "compile_plans_cached"), Some(12));
        assert_eq!(scrape_value(&text, "compile_fused_ops"), Some(24));
        assert_eq!(scrape_value(&text, "compile_folded_consts"), Some(24));
        assert_eq!(scrape_value(&text, "compile_arena_bytes"), Some(8192));
        assert_eq!(scrape_value(&text, "compile_arena_allocs_total"), Some(2));
        assert_eq!(scrape_value(&text, "compile_arena_reuses_total"), Some(98));
        assert_eq!(scrape_value(&text, "compile_train_trajectory_bytes"), Some(4096));
        assert_eq!(scrape_value(&text, "compile_train_recompute_segments"), Some(6));
        assert_eq!(scrape_value(&text, "compile_train_interp_nodes"), Some(5));
        assert_eq!(scrape_value(&text, "compile_train_arena_allocs_total"), Some(3));
        assert_eq!(scrape_value(&text, "compile_train_arena_reuses_total"), Some(97));
    }

    #[test]
    fn http_response_is_well_formed() {
        let body = "anode_submitted_total 1\n";
        let resp = http_response(body);
        let text = String::from_utf8(resp).unwrap();
        assert!(text.starts_with("HTTP/1.0 200 OK\r\n"));
        assert!(text.contains(&format!("Content-Length: {}\r\n", body.len())));
        assert!(text.ends_with(body));
    }
}
