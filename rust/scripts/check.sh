#!/usr/bin/env bash
# Tier-1 gate for the Rust workspace: formatting, lints, tests.
#
#   bash rust/scripts/check.sh          # from the repo root
#   bash rust/scripts/check.sh --bench  # also: quick benches + baseline gate
#
# Mirrors what CI runs (and what ROADMAP.md documents as the tier-1
# verify). Artifacts are NOT required: integration tests skip gracefully
# when artifacts/manifest.json is absent, and the offline build links the
# vendored xla stub (rust/vendor/xla-stub).
#
# --bench reproduces the CI bench-smoke job: every BENCH_*.json-producing
# bench in quick mode, then `bench_check`, which diffs the artifacts
# against the committed baselines in rust/bench-baselines/ (hard fail on
# a boolean invariant gone false or a missing artifact; ::warning:: on
# >30% latency drift). After a deliberate perf-affecting change, rewrite
# the baselines with `cargo run --bin bench_check -- --bless` and commit
# the rust/bench-baselines/ diff alongside the change (rust/DESIGN.md §6g).

set -euo pipefail

cd "$(dirname "$0")/../.."   # repo root (holds the workspace Cargo.toml)

echo "== cargo fmt --check =="
cargo fmt --all --check

echo "== cargo clippy -D warnings =="
cargo clippy --workspace --all-targets -- -D warnings

echo "== cargo test -q =="
cargo test -q --workspace

if [[ "${1:-}" == "--bench" ]]; then
    echo "== quick benches (ANODE_BENCH_QUICK=1) =="
    for bench in step_throughput net_throughput compile_throughput rollout_throughput; do
        ANODE_BENCH_QUICK=1 cargo bench --bench "$bench"
    done
    echo "== bench_check (baseline regression gate) =="
    cargo run --bin bench_check
fi

echo "== OK: fmt + clippy + tests clean =="
