#!/usr/bin/env bash
# Tier-1 gate for the Rust workspace: formatting, lints, tests.
#
#   bash rust/scripts/check.sh          # from the repo root
#
# Mirrors what CI runs (and what ROADMAP.md documents as the tier-1
# verify). Artifacts are NOT required: integration tests skip gracefully
# when artifacts/manifest.json is absent, and the offline build links the
# vendored xla stub (rust/vendor/xla-stub).

set -euo pipefail

cd "$(dirname "$0")/../.."   # repo root (holds the workspace Cargo.toml)

echo "== cargo fmt --check =="
cargo fmt --all --check

echo "== cargo clippy -D warnings =="
cargo clippy --workspace --all-targets -- -D warnings

echo "== cargo test -q =="
cargo test -q --workspace

echo "== OK: fmt + clippy + tests clean =="
