//! Compile-pipeline tests (`anode::compile` — rust/DESIGN.md §6f).
//!
//! The lock-ins: (1) compiled plans are **bit-identical** to the sim
//! interpreter for every manifest module; (2) DCE actually removes
//! unreferenced op chains — and lowering *requires* it to; (3) shape
//! inference rejects mismatched manifests at compile time with typed
//! errors; (4) fusion preserves the primitive-op accounting; (5) the
//! fused inference program's liveness-planned arena reuses slots and
//! performs zero steady-state allocations; (6) corrupt manifests fail
//! the compiled open with an error — never a panic.

use std::path::PathBuf;

use anode::api::{Engine, SessionConfig};
use anode::compile::{
    build_module_ir, compile_module, passes, plan::assign_slots, CompileError, InferCall,
    InferProgram, Op, OpKind,
};
use anode::runtime::sim::{write_artifacts, SimSpec};
use anode::runtime::{ArtifactRegistry, Backend, ModuleSpec, TensorSpec};
use anode::tensor::Tensor;

/// Every built-in gradient method — the compiled training path must hold
/// for all of them, not just the fused adjoint.
const STRATEGIES: [&str; 7] = [
    "anode",
    "node",
    "otd",
    "anode-revolve3",
    "anode-equispaced2",
    "symplectic",
    "interp-adjoint3",
];

/// Write the sim artifact set into a fresh temp dir.
fn sim_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("anode_compile_{}_{tag}", std::process::id()));
    write_artifacts(&dir, &SimSpec::default()).unwrap();
    dir
}

fn tensor_spec(name: &str, shape: &[usize]) -> TensorSpec {
    TensorSpec { name: name.into(), shape: shape.to_vec(), dtype: "f32".into() }
}

fn module_spec(name: &str, ins: &[&[usize]], outs: &[&[usize]]) -> ModuleSpec {
    ModuleSpec {
        name: name.into(),
        file: format!("{name}.hlo.txt"),
        inputs: ins.iter().enumerate().map(|(i, s)| tensor_spec(&format!("i{i}"), s)).collect(),
        outputs: outs.iter().enumerate().map(|(o, s)| tensor_spec(&format!("o{o}"), s)).collect(),
    }
}

/// Deterministic input data for a declared shape.
fn input_tensor(shape: &[usize], seed: usize) -> Tensor {
    let n: usize = shape.iter().product::<usize>().max(1);
    let data = (0..n).map(|j| ((seed * 37 + j) % 101) as f32 * 0.25 - 12.5).collect();
    Tensor::from_vec(shape.to_vec(), data).unwrap()
}

/// Every manifest module, called through the sim interpreter and through
/// its compiled plan, must produce bitwise-identical outputs — the core
/// claim of the compiled backend (shared value-model primitives make
/// this structural; the test locks the structure in).
#[test]
fn compiled_plans_bitwise_equal_to_sim_for_every_module() {
    let dir = sim_dir("bitwise");
    let sim = ArtifactRegistry::open_with_backend(&dir, 0, Backend::Sim).unwrap();
    let compiled = ArtifactRegistry::open_with_backend(&dir, 0, Backend::Compiled).unwrap();
    assert_eq!(sim.backend(), Backend::Sim);
    assert_eq!(compiled.backend(), Backend::Compiled);

    let names: Vec<String> = sim.module_names().iter().map(|n| n.to_string()).collect();
    assert!(!names.is_empty());
    for (k, name) in names.iter().enumerate() {
        let shapes: Vec<Vec<usize>> =
            sim.module_spec(name).unwrap().inputs.iter().map(|t| t.shape.clone()).collect();
        let inputs: Vec<Tensor> =
            shapes.iter().enumerate().map(|(i, s)| input_tensor(s, k * 11 + i)).collect();
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let a = sim.call(name, &refs).unwrap();
        let b = compiled.call(name, &refs).unwrap();
        assert_eq!(a.len(), b.len(), "{name}: output arity diverged");
        for (oi, (ta, tb)) in a.iter().zip(&b).enumerate() {
            assert_eq!(ta.shape(), tb.shape(), "{name} output {oi}: shape diverged");
            let bits_a: Vec<u32> = ta.data().iter().map(|v| v.to_bits()).collect();
            let bits_b: Vec<u32> = tb.data().iter().map(|v| v.to_bits()).collect();
            assert_eq!(bits_a, bits_b, "{name} output {oi}: bits diverged");
        }
        // The trusted path dispatches the same plans.
        let c = compiled.call_trusted(name, &refs).unwrap();
        for (ta, tc) in a.iter().zip(&c) {
            assert_eq!(ta.data(), tc.data(), "{name}: trusted dispatch diverged");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The compiled open eagerly caches a plan for every manifest module and
/// the pass counters carry the expected per-module structure: the whole
/// pre-data prefix folds (NameDigest + first MixLen = 2 per module) and
/// every module fuses at least its absorb chain.
#[test]
fn compiled_open_caches_every_module_with_pass_accounting() {
    let dir = sim_dir("cache");
    let reg = ArtifactRegistry::open_with_backend(&dir, 0, Backend::Compiled).unwrap();
    let stats = reg.compile_stats().expect("compiled registries expose stats");
    let modules = reg.module_names().len() as u64;
    assert_eq!(stats.plans_cached, modules);
    assert_eq!(stats.folded_consts, 2 * modules, "pre-data prefix folds per module");
    assert!(stats.fused_ops >= modules, "every absorb chain must fuse: {stats:?}");
    assert_eq!(stats.arena_allocs, 0, "no arena activity before any fused program runs");
    std::fs::remove_dir_all(&dir).ok();
}

/// DCE removes unreferenced op chains — and lowering depends on it: a
/// grafted dead chain makes the raw IR non-lowerable (the digest graph
/// is no longer a single chain), while the DCE'd IR lowers to exactly
/// the plan of the clean module.
#[test]
fn dce_removes_unreferenced_chains_and_unblocks_lowering() {
    let spec = module_spec("m", &[&[4], &[2]], &[&[3]]);
    let clean = compile_module(&spec).unwrap();

    let mut ir = build_module_ir(&spec).unwrap();
    let id = ir.fresh_id();
    ir.ops.push(Op { id, kind: OpKind::NameDigest });
    ir.ops.push(Op { id: id + 1, kind: OpKind::MixLen { src: id, len: 9 } });
    ir.ops.push(Op { id: id + 2, kind: OpKind::AbsorbData { src: id + 1, input: 0 } });

    let err = anode::compile::plan::lower_module(&ir).unwrap_err();
    assert!(
        matches!(err, CompileError::Unsupported { ref reason, .. } if reason.contains("chain")),
        "dead code must make raw lowering fail typed: {err}"
    );

    let removed = passes::dce(&mut ir);
    assert_eq!(removed, 3, "the whole grafted chain is unreachable");
    let lowered = anode::compile::plan::lower_module(&ir).unwrap();
    // The DCE'd raw IR and the fully passed pipeline compute the same
    // function — same bits on the same inputs.
    let inputs = [input_tensor(&[4], 1), input_tensor(&[2], 2)];
    let refs: Vec<&Tensor> = inputs.iter().collect();
    let a = lowered.execute(&refs).unwrap();
    let b = clean.execute(&refs).unwrap();
    assert_eq!(a[0].data(), b[0].data(), "lowering paths diverged");
}

/// Cross-module shape inference over an inference chain: every way a
/// manifest (or chain) can disagree surfaces as the matching typed
/// [`CompileError`] at build time — never at call time.
#[test]
fn infer_chain_shape_inference_rejects_mismatches_with_typed_errors() {
    let dir = sim_dir("shapes");
    let reg = ArtifactRegistry::open_with_backend(&dir, 0, Backend::Compiled).unwrap();
    let layout: Vec<Vec<usize>> =
        reg.param_layout("resnet10").unwrap().iter().map(|p| p.shape.clone()).collect();
    let call = |module: &str, params: &[usize]| InferCall {
        module: module.into(),
        params: params.to_vec(),
    };

    let err = InferProgram::build(&reg, &[call("nope", &[])], &layout).unwrap_err();
    assert_eq!(err, CompileError::MissingModule { module: "nope".into() });

    let err = InferProgram::build(&reg, &[call("stem_fwd", &[0])], &layout).unwrap_err();
    assert!(matches!(err, CompileError::ArityMismatch { expected: 3, found: 2, .. }), "{err}");

    // Swapped parameter indices: w receives b's shape.
    let err = InferProgram::build(&reg, &[call("stem_fwd", &[1, 0])], &layout).unwrap_err();
    assert!(
        matches!(err, CompileError::ShapeMismatch { ref module, ref input, .. }
            if module == "stem_fwd" && input == "w"),
        "{err}"
    );

    // Chained activation mismatch: stem output feeds stem input again.
    let chain = [call("stem_fwd", &[0, 1]), call("stem_fwd", &[0, 1])];
    let err = InferProgram::build(&reg, &chain, &layout).unwrap_err();
    assert!(
        matches!(err, CompileError::ShapeMismatch { ref input, .. } if input == "x"),
        "{err}"
    );

    // Multi-output modules cannot join a fused single-activation chain.
    let err = InferProgram::build(&reg, &[call("head10_loss_grad", &[8, 9, 1])], &layout)
        .unwrap_err();
    assert!(
        matches!(err, CompileError::Unsupported { ref reason, .. }
            if reason.contains("single-output")),
        "{err}"
    );

    // A sim registry has no compiled set to build against.
    let sim = ArtifactRegistry::open_with_backend(&dir, 0, Backend::Sim).unwrap();
    let err = InferProgram::build(&sim, &[call("stem_fwd", &[0, 1])], &layout).unwrap_err();
    assert!(
        matches!(err, CompileError::Unsupported { ref reason, .. }
            if reason.contains("compiled backend")),
        "{err}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Fusion preserves the primitive-op accounting on every real manifest
/// module: the plan covers exactly the primitives of its unfused IR.
#[test]
fn fusion_preserves_op_count_accounting_across_the_manifest() {
    let dir = sim_dir("fusion");
    let reg = ArtifactRegistry::open_with_backend(&dir, 0, Backend::Sim).unwrap();
    for name in reg.module_names() {
        let spec = reg.module_spec(name).unwrap();
        let raw = build_module_ir(spec).unwrap();
        let primitives = raw.primitive_count();
        let plan = compile_module(spec).unwrap();
        assert_eq!(
            plan.primitive_count(),
            primitives,
            "{name}: fusion must account for every primitive"
        );
        assert!(plan.fused_ops() >= 1, "{name}: the absorb chain must fuse");
        assert_eq!(plan.folded_consts(), 2, "{name}: the pre-data prefix folds");
        assert_eq!(plan.input_count(), spec.inputs.len());
        assert_eq!(plan.output_count(), spec.outputs.len());
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Liveness-interval slot assignment: a linear chain ping-pongs two
/// slots (a value can never alias an operand still being read), slots
/// size to their largest resident, and disjoint lifetimes share.
#[test]
fn assign_slots_reuses_buffers_without_aliasing() {
    // Linear chain: v0 read by v1's def, v2 reuses v0's slot.
    let (slots, sizes) = assign_slots(&[(0, 1, 10), (1, 2, 4), (2, 3, 6)]);
    assert_eq!(slots, vec![0, 1, 0]);
    assert_eq!(sizes, vec![10, 4]);

    // Adjacent values must not share: v1 is defined while v0 is read.
    let (slots, _) = assign_slots(&[(0, 1, 8), (1, 2, 8)]);
    assert_eq!(slots, vec![0, 1], "in/out aliasing would corrupt the digest");

    // Disjoint lifetimes share one slot sized to the max.
    let (slots, sizes) = assign_slots(&[(0, 1, 5), (2, 3, 7)]);
    assert_eq!(slots, vec![0, 0]);
    assert_eq!(sizes, vec![7]);
}

/// The fused inference program: bit-identical to the sequential
/// module-call chain, two arena slots for the linear forward, and zero
/// steady-state allocations (the pool hands the arena back after the
/// first run — the shared counters prove it).
#[test]
fn infer_program_arena_reuse_and_bitwise_identity() {
    let dir = sim_dir("arena");
    let reg = ArtifactRegistry::open_with_backend(&dir, 0, Backend::Compiled).unwrap();
    let layout: Vec<Vec<usize>> =
        reg.param_layout("resnet10").unwrap().iter().map(|p| p.shape.clone()).collect();
    // The SimSpec::default forward: stem → s0 block → trans0 → s1 block.
    let chain = [
        InferCall { module: "stem_fwd".into(), params: vec![0, 1] },
        InferCall { module: "block_resnet_s0_euler_fwd".into(), params: vec![2, 3] },
        InferCall { module: "trans0_fwd".into(), params: vec![4, 5] },
        InferCall { module: "block_resnet_s1_euler_fwd".into(), params: vec![6, 7] },
    ];
    let prog = InferProgram::build(&reg, &chain, &layout).unwrap();
    assert_eq!(prog.len(), chain.len());
    assert_eq!(prog.slot_count(), 2, "a linear chain ping-pongs two slots");
    assert_eq!(prog.out_shape(), &[4, 4, 4, 8]);
    // Stage-0 activations are [4, 8, 8, 4] = 1024 elements; both slots
    // size to that largest resident.
    let act0 = 1024usize;
    assert_eq!(
        prog.arena_bytes(),
        2 * act0 * std::mem::size_of::<f32>(),
        "both slots size to the stage-0 activation"
    );

    let params = reg.load_params("resnet10").unwrap();
    let x = SimSpec::default().image_batch(7);

    // Sequential reference through the registry.
    let mut z = reg.call("stem_fwd", &[&x, &params[0], &params[1]]).unwrap().remove(0);
    z = reg
        .call("block_resnet_s0_euler_fwd", &[&z, &params[2], &params[3]])
        .unwrap()
        .remove(0);
    z = reg.call("trans0_fwd", &[&z, &params[4], &params[5]]).unwrap().remove(0);
    z = reg
        .call("block_resnet_s1_euler_fwd", &[&z, &params[6], &params[7]])
        .unwrap()
        .remove(0);

    let before = reg.compile_stats().unwrap();
    assert_eq!(before.arena_allocs, 0);
    let y1 = prog.run(&x, &params).unwrap();
    let y2 = prog.run(&x, &params).unwrap();
    assert_eq!(y1.data(), z.data(), "fused program diverged from the sequential chain");
    assert_eq!(y1.data(), y2.data(), "rerun must be deterministic");

    let after = reg.compile_stats().unwrap();
    assert_eq!(after.arena_allocs, 1, "exactly one warmup allocation");
    assert_eq!(after.arena_reuses, 1, "the second run reuses the pooled arena");
    assert_eq!(after.arena_bytes, prog.arena_bytes() as u64);

    // Steady state: ten more runs, zero further allocations.
    for _ in 0..10 {
        prog.run(&x, &params).unwrap();
    }
    let steady = reg.compile_stats().unwrap();
    assert_eq!(steady.arena_allocs, 1, "steady state must not allocate");
    assert_eq!(steady.arena_reuses, 11);
    std::fs::remove_dir_all(&dir).ok();
}

/// The fused training program, per strategy: loss, correct count and
/// **every gradient tensor** bitwise equal to the sim interpreter —
/// before any optimizer arithmetic — and full optimizer steps keep
/// losses and parameters bitwise locked too. This is the training-side
/// counterpart of the per-module bitwise test above: the whole
/// forward + strategy backward + loss/grad tail as one arena program.
#[test]
fn train_program_bitwise_equal_to_sim_for_every_strategy() {
    let dir = sim_dir("train_bitwise");
    let sim =
        Engine::builder().artifacts(&dir).devices(1).backend(Backend::Sim).build().unwrap();
    let compiled =
        Engine::builder().artifacts(&dir).devices(1).backend(Backend::Compiled).build().unwrap();
    let spec = SimSpec::default();
    for method in STRATEGIES {
        let mut a = sim.session(SessionConfig::with_method(method)).unwrap();
        let mut b = compiled.session(SessionConfig::with_method(method)).unwrap();

        // Raw loss + correct + gradients first: the strongest form of
        // the invariant, before clipping or SGD touch anything.
        let (x, y) = (spec.image_batch(5), spec.label_batch(5));
        let (la, ca, ga) = a.loss_and_grad(&x, &y).unwrap();
        let (lb, cb, gb) = b.loss_and_grad(&x, &y).unwrap();
        assert_eq!(la.to_bits(), lb.to_bits(), "{method}: loss bits diverged");
        assert_eq!(ca.to_bits(), cb.to_bits(), "{method}: correct-count bits diverged");
        assert_eq!(ga.len(), gb.len(), "{method}: gradient arity diverged");
        for (i, (ta, tb)) in ga.iter().zip(&gb).enumerate() {
            assert_eq!(ta.shape(), tb.shape(), "{method} grad {i}: shape diverged");
            let bits_a: Vec<u32> = ta.data().iter().map(|v| v.to_bits()).collect();
            let bits_b: Vec<u32> = tb.data().iter().map(|v| v.to_bits()).collect();
            assert_eq!(bits_a, bits_b, "{method} grad {i}: bits diverged");
        }

        // Then full optimizer steps: stats and parameters stay bitwise.
        for step in 0..3 {
            let (x, y) = (spec.image_batch(step), spec.label_batch(step));
            let sa = a.step(&x, &y).unwrap();
            let sb = b.step(&x, &y).unwrap();
            assert_eq!(sa.loss.to_bits(), sb.loss.to_bits(), "{method} step {step}: loss");
            assert_eq!(
                sa.batch_accuracy.to_bits(),
                sb.batch_accuracy.to_bits(),
                "{method} step {step}: accuracy"
            );
            assert_eq!(
                sa.grad_norm.to_bits(),
                sb.grad_norm.to_bits(),
                "{method} step {step}: grad norm"
            );
        }
        for (i, (pa, pb)) in a.params().iter().zip(b.params()).enumerate() {
            let bits_a: Vec<u32> = pa.data().iter().map(|v| v.to_bits()).collect();
            let bits_b: Vec<u32> = pb.data().iter().map(|v| v.to_bits()).collect();
            assert_eq!(bits_a, bits_b, "{method} param {i}: bits diverged after training");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The training arena's hard allocation invariant: lowering a session's
/// strategy plans the trajectory slots (visible in the build-time
/// counters), the first step pays exactly one arena allocation, and
/// every steady-state step after warmup performs **zero** allocations —
/// the pooled-arena counters prove it, the same pattern the inference
/// program locks in above.
#[test]
fn train_program_zero_steady_state_allocations_after_warmup() {
    let dir = sim_dir("train_arena");
    let engine =
        Engine::builder().artifacts(&dir).devices(1).backend(Backend::Compiled).build().unwrap();
    let reg = engine.registry();
    let spec = SimSpec::default();
    let base = reg.compile_stats().unwrap();
    assert_eq!(base.train_arena_allocs, 0);
    assert_eq!(base.trajectory_bytes, 0, "no training program lowered yet");

    // Session creation lowers the strategy into a TrainProgram: the
    // trajectory budget and revolve recompute segments appear at build
    // time, arena activity does not.
    let mut session = engine.session(SessionConfig::with_method("anode-revolve3")).unwrap();
    let built = reg.compile_stats().unwrap();
    assert!(built.trajectory_bytes > 0, "checkpoint slots must be planned into the arena");
    assert!(built.train_recompute_segments > 0, "revolve must unroll recompute segments");
    assert_eq!(built.train_arena_allocs, 0, "no arena activity before the first step");

    // Warmup allocates the single arena; steady state only reuses it.
    let (x, y) = (spec.image_batch(0), spec.label_batch(0));
    session.step(&x, &y).unwrap();
    let warm = reg.compile_stats().unwrap();
    assert_eq!(warm.train_arena_allocs, 1, "exactly one warmup allocation");
    assert_eq!(warm.train_arena_reuses, 0);
    for _ in 0..10 {
        session.step(&x, &y).unwrap();
    }
    let steady = reg.compile_stats().unwrap();
    assert_eq!(steady.train_arena_allocs, 1, "steady-state steps must not allocate");
    assert_eq!(steady.train_arena_reuses, 10, "every steady-state step reuses the arena");

    // A fused-adjoint session on the same registry plans boundary slots
    // but no recompute segments on top of the revolve session's.
    let fused = engine.session(SessionConfig::with_method("anode")).unwrap();
    let after = reg.compile_stats().unwrap();
    assert_eq!(
        after.train_recompute_segments, steady.train_recompute_segments,
        "the fused adjoint replays nothing"
    );
    assert!(after.trajectory_bytes > steady.trajectory_bytes, "block boundaries still planned");
    drop(fused);
    std::fs::remove_dir_all(&dir).ok();
}

/// Interpolated-adjoint lowering pins its interior node states in
/// long-lived arena slots at build time: the `train_interp_nodes`
/// counter reports them, they join the trajectory budget, and no other
/// strategy pins any (symplectic stores everything but interpolates
/// nothing).
#[test]
fn interp_adjoint_lowering_pins_node_states_at_build_time() {
    let dir = sim_dir("interp_nodes");
    let engine =
        Engine::builder().artifacts(&dir).devices(1).backend(Backend::Compiled).build().unwrap();
    let reg = engine.registry();
    assert_eq!(reg.compile_stats().unwrap().train_interp_nodes, 0);

    let symp = engine.session(SessionConfig::with_method("symplectic")).unwrap();
    let after_symp = reg.compile_stats().unwrap();
    assert_eq!(after_symp.train_interp_nodes, 0, "symplectic pins no interpolation nodes");
    assert!(after_symp.trajectory_bytes > 0, "store-everything tape must be planned");

    // interp-adjoint3 over the SimSpec nt = 4 grid places nodes {0, 2, 4}
    // — one interior node per block, over stages × blocks_per_stage = 2
    // blocks.
    let interp = engine.session(SessionConfig::with_method("interp-adjoint3")).unwrap();
    let after = reg.compile_stats().unwrap();
    assert_eq!(after.train_interp_nodes, 2, "one interior node pinned per block");
    assert!(
        after.trajectory_bytes > after_symp.trajectory_bytes,
        "pinned nodes must join the trajectory budget"
    );
    drop((symp, interp));
    std::fs::remove_dir_all(&dir).ok();
}

/// Corrupt-manifest fuzz: targeted mutations and deterministic
/// truncations of a valid manifest. Opening the compiled backend must
/// return an error in every case — never panic, never defer the failure
/// to call time.
#[test]
fn corrupt_manifests_fail_compiled_open_without_panicking() {
    let dir = sim_dir("fuzz");
    let manifest_path = dir.join("manifest.json");
    let pristine = std::fs::read_to_string(&manifest_path).unwrap();

    // Sanity: the pristine manifest compiles.
    assert!(ArtifactRegistry::open_with_backend(&dir, 0, Backend::Compiled).is_ok());

    let zero_dim = pristine
        .replace("{\"name\":\"loss\",\"shape\":[1]", "{\"name\":\"loss\",\"shape\":[1,0]");
    let no_outputs = pristine.replace(
        "\"outputs\":[{\"name\":\"z\",",
        "\"outputs\":[],\"unused\":[{\"name\":\"z\",",
    );
    let mutations: Vec<(&str, String)> = vec![
        ("unsupported dtype", pristine.replacen("\"f32\"", "\"i32\"", 1)),
        ("zero-dim output", zero_dim),
        ("no outputs", no_outputs),
        ("not json", pristine.replace(':', ";")),
        ("empty file", String::new()),
    ];
    for (what, text) in &mutations {
        assert_ne!(text, &pristine, "mutation `{what}` must change the manifest");
        std::fs::write(&manifest_path, text).unwrap();
        let result = ArtifactRegistry::open_with_backend(&dir, 0, Backend::Compiled);
        assert!(result.is_err(), "mutation `{what}` must fail the compiled open");
    }

    // Deterministic truncation sweep — malformed JSON at every cut.
    for i in 1..8 {
        let cut = pristine.len() * i / 8;
        std::fs::write(&manifest_path, &pristine[..cut]).unwrap();
        let result = ArtifactRegistry::open_with_backend(&dir, 0, Backend::Compiled);
        assert!(result.is_err(), "truncation at {cut} bytes must fail the open");
    }

    // Restore: the artifacts open again (no state was corrupted).
    std::fs::write(&manifest_path, &pristine).unwrap();
    assert!(ArtifactRegistry::open_with_backend(&dir, 0, Backend::Compiled).is_ok());
    std::fs::remove_dir_all(&dir).ok();
}
