//! Property-based tests over coordinator invariants (hand-rolled generator
//! loop — the offline image has no proptest crate; `anode::rng` provides
//! the deterministic entropy and failures print the seed for replay).

use anode::checkpoint::{min_recomputations, plan, run_backward, Strategy};
use anode::data::{Batcher, SyntheticCifar};
use anode::memory::{Category, MemoryLedger};
use anode::rng::Rng;
use anode::tensor::Tensor;
use anode::util::pool::ShardRouter;

/// Run `f` over `n` random cases, reporting the failing seed.
fn forall(name: &str, n: u64, mut f: impl FnMut(&mut Rng)) {
    for seed in 0..n {
        let mut rng = Rng::new(0x5EED_0000 ^ seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property {name} failed at seed {seed}: {e:?}");
        }
    }
}

#[test]
fn prop_all_schedules_valid_and_within_budget() {
    forall("schedule_validity", 200, |rng| {
        let nt = 1 + rng.below(40);
        let m = 1 + rng.below(10);
        let strategy = match rng.below(4) {
            0 => Strategy::StoreAll,
            1 => Strategy::MinMemory,
            2 => Strategy::Equispaced(m),
            _ => Strategy::Revolve(m),
        };
        let s = plan(strategy, nt);
        let errs = s.validate();
        assert!(errs.is_empty(), "nt={nt} {strategy:?}: {errs:?}");
        assert!(s.peak_slots() <= strategy.slots(nt), "slots exceeded: nt={nt} {strategy:?}");
        // Every step's VJP runs exactly once: validate() checks ordering,
        // forward_evals sanity-checks the cost model.
        assert!(s.forward_evals() >= nt.min(s.nt));
    });
}

#[test]
fn prop_revolve_gradient_exact_for_random_affine_dynamics() {
    forall("revolve_exactness", 60, |rng| {
        let nt = 1 + rng.below(24);
        let m = 1 + rng.below(6);
        // Random affine map per run: z' = a z + b (same every step).
        let a = (0.8 + rng.uniform() * 0.4) as f64;
        let b = rng.normal() as f64 * 0.1;
        let z0 = rng.normal() as f64;
        let step = |z: &f64| a * z + b;
        let dstep = |_z: &f64, adj: &f64| a * adj;
        let g_rev =
            run_backward(&plan(Strategy::Revolve(m), nt), &z0, 1.0, step, dstep, |_| {}).unwrap();
        let g_all =
            run_backward(&plan(Strategy::StoreAll, nt), &z0, 1.0, step, dstep, |_| {}).unwrap();
        assert!((g_rev - g_all).abs() < 1e-12, "nt={nt} m={m}: {g_rev} vs {g_all}");
        // Analytic: d z_nt / d z_0 = a^nt.
        assert!((g_rev - a.powi(nt as i32)).abs() < 1e-9 * a.powi(nt as i32).abs());
    });
}

#[test]
fn prop_revolve_cost_optimal_and_monotone() {
    forall("revolve_cost", 100, |rng| {
        let nt = 2 + rng.below(40);
        let m = 1 + rng.below(8);
        let c_m = min_recomputations(nt, m);
        let c_m1 = min_recomputations(nt, m + 1);
        assert!(c_m1 <= c_m, "more memory must not cost more: nt={nt} m={m}");
        // Bounds: never better than one taped pass, never worse than O(nt²).
        assert!(c_m >= nt as u64);
        assert!(c_m <= (nt * (nt + 1) / 2) as u64);
        // Plan cost == DP cost.
        assert_eq!(plan(Strategy::Revolve(m), nt).forward_evals() as u64, c_m);
    });
}

#[test]
fn prop_batcher_partitions_dataset() {
    forall("batcher_partition", 30, |rng| {
        let n = 8 + rng.below(64);
        let bsz = 1 + rng.below(n.min(16));
        // Identifiable "images": value = index.
        let mut data = vec![0.0f32; n];
        for (i, d) in data.iter_mut().enumerate() {
            *d = i as f32;
        }
        let imgs = Tensor::from_vec(vec![n, 1, 1, 1], data).unwrap();
        let labels: Vec<usize> = (0..n).map(|i| i % 5).collect();
        let mut b = Batcher::new(imgs, labels, bsz, false, rng.next_u64()).unwrap();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..(n / bsz) {
            let batch = b.next_batch();
            for k in 0..bsz {
                let idx = batch.images.data()[k] as usize;
                assert!(seen.insert(idx), "index {idx} repeated within epoch");
            }
        }
    });
}

#[test]
fn prop_ledger_peak_monotone_and_exact() {
    forall("ledger", 50, |rng| {
        let mut led = MemoryLedger::new();
        let mut live: Vec<(u64, usize)> = Vec::new();
        let mut cur = 0usize;
        let mut peak = 0usize;
        for _ in 0..200 {
            if live.is_empty() || rng.uniform() < 0.6 {
                let bytes = 1 + rng.below(1000);
                let id = led.alloc(bytes, Category::StepState);
                live.push((id, bytes));
                cur += bytes;
                peak = peak.max(cur);
            } else {
                let k = rng.below(live.len());
                let (id, bytes) = live.swap_remove(k);
                led.free(id);
                cur -= bytes;
            }
            assert_eq!(led.current_bytes(), cur);
            assert_eq!(led.peak_bytes(), peak);
        }
    });
}

#[test]
fn prop_synthetic_cifar_deterministic_and_finite() {
    forall("cifar", 10, |rng| {
        let ncls = [10, 100][rng.below(2)];
        let seed = rng.next_u64();
        let ds1 = SyntheticCifar::new(ncls, seed, 0.1);
        let ds2 = SyntheticCifar::new(ncls, seed, 0.1);
        let (a, la) = ds1.generate(32, 1);
        let (b, lb) = ds2.generate(32, 1);
        assert_eq!(a.data(), b.data());
        assert_eq!(la, lb);
        assert!(a.all_finite());
        assert!(la.iter().all(|&l| l < ncls));
    });
}

#[test]
fn prop_equispaced_never_beats_revolve() {
    forall("equispaced_vs_revolve", 80, |rng| {
        let nt = 2 + rng.below(40);
        let m = 1 + rng.below(8);
        let e = plan(Strategy::Equispaced(m), nt).forward_evals();
        let r = plan(Strategy::Revolve(m), nt).forward_evals();
        assert!(r <= e, "nt={nt} m={m}: revolve {r} > equispaced {e}");
    });
}

#[test]
fn prop_shard_router_conserves_items_and_never_reorders() {
    forall("shard_router_plan", 150, |rng| {
        let ndev = 1 + rng.below(5);
        let caps: Vec<usize> = (0..ndev).map(|_| 1 + rng.below(4)).collect();
        let router = ShardRouter::new(&caps);
        let n = rng.below(240);
        let chunk = 1 + rng.below(17);
        let assignments = router.assign_chunks(n, chunk);
        // Contiguous, in input order, conserving every item — the output
        // reassembly can therefore never reorder, whatever the routing.
        let mut next = 0usize;
        for a in &assignments {
            assert!(a.device < ndev, "device out of range");
            assert!(a.len >= 1 && a.len <= chunk, "chunk length out of bounds");
            assert_eq!(a.start, next, "chunks must be contiguous and ordered");
            next += a.len;
        }
        assert_eq!(next, n, "assignments must conserve the total item count");
        // Loads reflect exactly the outstanding assignment...
        let loads = router.loads();
        assert_eq!(loads.iter().sum::<u64>(), n as u64);
        // ...and drain back to zero as chunks complete (ticket or manual).
        for a in &assignments {
            router.complete(a.device, a.len as u64);
        }
        assert!(router.loads().iter().all(|&l| l == 0), "load must drain to zero");
    });
}

#[test]
fn prop_shard_router_never_starves_a_device() {
    forall("shard_router_starvation", 150, |rng| {
        let ndev = 1 + rng.below(5);
        let caps: Vec<usize> = (0..ndev).map(|_| 1 + rng.below(4)).collect();
        let router = ShardRouter::new(&caps);
        // Pre-load some devices arbitrarily (simulating in-flight work),
        // then drain it — the plan below starts balanced.
        for _ in 0..rng.below(8) {
            let d = router.acquire(1 + rng.below(5) as u64);
            let l = router.loads()[d];
            router.complete(d, l);
        }
        let chunk = 1 + rng.below(9);
        let n = chunk * (ndev + rng.below(3 * ndev));
        let assignments = router.assign_chunks(n, chunk);
        // From a balanced start, an idle device always beats a loaded one
        // — so with at least as many chunks as devices, every device
        // receives work (no starvation).
        if assignments.len() >= ndev {
            let mut fed = vec![false; ndev];
            for a in &assignments {
                fed[a.device] = true;
            }
            assert!(
                fed.iter().all(|&f| f),
                "starved device: caps={caps:?} n={n} chunk={chunk} fed={fed:?}"
            );
        }
        // Higher-capacity devices never receive *fewer* items than a
        // strictly lower-capacity device from a balanced start (load is
        // normalized by capacity).
        let mut items = vec![0u64; ndev];
        for a in &assignments {
            items[a.device] += a.len as u64;
        }
        for hi in 0..ndev {
            for lo in 0..ndev {
                if caps[hi] > caps[lo] {
                    assert!(
                        items[hi] + chunk as u64 >= items[lo],
                        "capacity-starved device: caps={caps:?} items={items:?}"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_tensor_axpy_matches_reference() {
    forall("axpy", 40, |rng| {
        let n = 1 + rng.below(100);
        let a = rng.normal();
        let xv = rng.normal_vec(n);
        let yv = rng.normal_vec(n);
        let x = Tensor::from_vec(vec![n], xv.clone()).unwrap();
        let mut y = Tensor::from_vec(vec![n], yv.clone()).unwrap();
        y.axpy(a, &x).unwrap();
        for i in 0..n {
            let expect = yv[i] + a * xv[i];
            assert!((y.data()[i] - expect).abs() <= 1e-5 * (1.0 + expect.abs()));
        }
    });
}
