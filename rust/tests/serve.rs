//! Integration tests for `anode::serve` — the deadline-batched admission
//! queue on the persistent worker pool.
//!
//! Stub-safe tests drive the pipeline with a deterministic host-side
//! `TestRunner` (no artifacts needed): deadline vs full-batch flushes,
//! submission-order reply demultiplexing with bit-identical values,
//! bounded-queue backpressure, clean shutdown draining, and per-worker
//! ledger merge accounting. The artifact-gated test at the bottom asserts
//! the serve path is bit-identical to `Session::predict_batches` on the
//! real engine for several (workers, max_delay) combinations.

use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anode::api::{argmax_rows, head_logits, Engine, Prediction, PredictStats, SessionConfig};
use anode::data::SyntheticCifar;
use anode::memory::{Category, MemoryLedger};
use anode::runtime::Result;
use anode::serve::{split_examples, BatchRunner, HostTailRunner, Pending, ServeConfig, ServeHandle};
use anode::tensor::Tensor;

const WAIT: Duration = Duration::from_secs(20);

/// Manually released latch blocking the runner, so tests can hold the
/// pipeline busy deterministically.
struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn new() -> Arc<Gate> {
        Arc::new(Gate { open: Mutex::new(false), cv: Condvar::new() })
    }

    fn release(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn wait_open(&self) {
        let mut open = self.open.lock().unwrap();
        while !*open {
            open = self.cv.wait(open).unwrap();
        }
    }
}

/// Deterministic host-side model: each row's logits are a fixed linear
/// function of that row's sum, so serve replies can be compared bitwise
/// against a direct batch run of the same function.
struct TestRunner {
    batch: usize,
    shape: Vec<usize>,
    k: usize,
    bytes_per_batch: usize,
    gate: Option<Arc<Gate>>,
    entered: Arc<AtomicUsize>,
}

impl TestRunner {
    fn new(batch: usize, shape: &[usize], k: usize) -> Self {
        Self {
            batch,
            shape: shape.to_vec(),
            k,
            bytes_per_batch: 1000,
            gate: None,
            entered: Arc::new(AtomicUsize::new(0)),
        }
    }

    fn row_logits(&self, row: &[f32]) -> Vec<f32> {
        let s: f32 = row.iter().sum();
        (0..self.k).map(|j| s * (j as f32 + 1.0) - j as f32).collect()
    }
}

impl BatchRunner for TestRunner {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn example_shape(&self) -> Vec<usize> {
        self.shape.clone()
    }

    fn run(&self, images: &Tensor, ledger: &mut MemoryLedger) -> Result<Prediction> {
        self.entered.fetch_add(1, Ordering::SeqCst);
        if let Some(gate) = &self.gate {
            gate.wait_open();
        }
        let id = ledger.alloc(self.bytes_per_batch, Category::Transient);
        let ex_len: usize = self.shape.iter().product();
        let mut out = Vec::with_capacity(self.batch * self.k);
        for row in images.data().chunks(ex_len) {
            out.extend(self.row_logits(row));
        }
        ledger.free(id);
        let logits = Tensor::from_vec(vec![self.batch, self.k], out).unwrap();
        let classes = argmax_rows(&logits);
        Ok(Prediction {
            classes,
            logits,
            stats: PredictStats {
                batch: self.batch,
                seconds: 0.0,
                examples_per_sec: 0.0,
                peak_activation_bytes: self.bytes_per_batch,
            },
        })
    }
}

/// Deterministic example tensor, distinct per seed.
fn example(shape: &[usize], seed: usize) -> Tensor {
    let len: usize = shape.iter().product();
    let data = (0..len).map(|j| ((seed * 31 + j) as f32) * 0.01 - 1.0).collect();
    Tensor::from_vec(shape.to_vec(), data).unwrap()
}

fn wait_until(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < timeout {
        if cond() {
            return true;
        }
        thread::sleep(Duration::from_millis(2));
    }
    cond()
}

#[test]
fn full_batch_flushes_immediately() {
    let shape = [2, 3];
    let runner = Arc::new(TestRunner::new(4, &shape, 3));
    // max_delay is 10 min: if the batch did not flush on filling, the
    // replies below would time out long before the deadline fires.
    let config = ServeConfig::default().max_delay_ms(600_000).workers(2).queue_cap(64);
    let handle = ServeHandle::spawn(runner, config).unwrap();
    let t0 = Instant::now();
    let pendings: Vec<Pending> =
        (0..4).map(|i| handle.submit(example(&shape, i)).unwrap()).collect();
    for pending in pendings {
        let reply = pending.wait_timeout(WAIT).unwrap().expect("reply before deadline");
        assert_eq!(reply.stats.batch_fill, 4);
        assert_eq!(reply.stats.batch_size, 4);
    }
    assert!(t0.elapsed() < Duration::from_secs(60), "flush waited for the deadline");
    let stats = handle.stats();
    assert_eq!(stats.full_flushes, 1, "{stats:?}");
    assert_eq!(stats.deadline_flushes, 0, "{stats:?}");
    handle.shutdown().unwrap();
}

#[test]
fn deadline_flush_fires_partial_batch_at_max_delay() {
    let shape = [2, 2];
    let runner = Arc::new(TestRunner::new(8, &shape, 3));
    let config = ServeConfig::default().max_delay_ms(150).workers(1).queue_cap(64);
    let handle = ServeHandle::spawn(runner, config).unwrap();
    let t0 = Instant::now();
    let pendings: Vec<Pending> =
        (0..3).map(|i| handle.submit(example(&shape, i)).unwrap()).collect();
    for pending in pendings {
        let reply = pending.wait_timeout(WAIT).unwrap().expect("deadline flush never fired");
        // 3 requests against a batch of 8: every flush is partial (a CI
        // scheduling pause may split them across several deadline windows).
        assert!(reply.stats.batch_fill < 8, "partial batch expected");
        assert_eq!(reply.stats.batch_size, 8);
    }
    let elapsed = t0.elapsed();
    assert!(elapsed >= Duration::from_millis(100), "flushed too early: {elapsed:?}");
    let stats = handle.stats();
    assert!(stats.deadline_flushes >= 1, "{stats:?}");
    assert_eq!(stats.full_flushes, 0, "{stats:?}");
    handle.shutdown().unwrap();
}

#[test]
fn replies_preserve_submission_order_and_match_direct_batches() {
    let shape = [2, 2];
    let (batch, k, n) = (4usize, 3usize, 12usize);
    let examples: Vec<Tensor> = (0..n).map(|i| example(&shape, i)).collect();

    // Expected values: stack submission-order groups of `batch` and run
    // the same deterministic function directly.
    let reference = TestRunner::new(batch, &shape, k);
    let ex_len: usize = shape.iter().product();
    let mut expected: Vec<(usize, Vec<f32>)> = Vec::with_capacity(n);
    let mut ledger = MemoryLedger::new();
    for group in examples.chunks(batch) {
        let mut stacked = Tensor::zeros(&[batch, shape[0], shape[1]]);
        for (i, ex) in group.iter().enumerate() {
            stacked.data_mut()[i * ex_len..(i + 1) * ex_len].copy_from_slice(ex.data());
        }
        let pred = reference.run(&stacked, &mut ledger).unwrap();
        for i in 0..group.len() {
            expected.push((pred.classes[i], pred.logits.data()[i * k..(i + 1) * k].to_vec()));
        }
    }

    // Values must be identical for every (workers, max_delay) combination:
    // deadline flushes re-batch rows at different positions, but each
    // row's computation depends only on that row.
    for (workers, delay_ms) in [(1usize, 1u64), (1, 200), (3, 1), (3, 200)] {
        let runner = Arc::new(TestRunner::new(batch, &shape, k));
        let config = ServeConfig::default().max_delay_ms(delay_ms).workers(workers).queue_cap(64);
        let handle = ServeHandle::spawn(runner, config).unwrap();
        let pendings: Vec<Pending> =
            examples.iter().map(|ex| handle.submit(ex.clone()).unwrap()).collect();
        for (i, pending) in pendings.into_iter().enumerate() {
            let reply = pending.wait_timeout(WAIT).unwrap().expect("reply");
            let (class, logits) = &expected[i];
            assert_eq!(reply.class, *class, "request {i} workers={workers} delay={delay_ms}");
            assert_eq!(
                reply.logits.data(),
                logits.as_slice(),
                "request {i} workers={workers} delay={delay_ms}"
            );
        }
        let report = handle.shutdown().unwrap();
        assert_eq!(report.requests, n as u64, "workers={workers} delay={delay_ms}");
    }
}

#[test]
fn bounded_queue_applies_backpressure_at_queue_cap() {
    let shape = [2, 2];
    let gate = Gate::new();
    let mut runner = TestRunner::new(1, &shape, 3);
    runner.gate = Some(gate.clone());
    let entered = runner.entered.clone();
    // batch=1, workers=1, queue_cap=1 with a gated runner: once the worker
    // is stuck inside the first batch, the pipeline absorbs exactly 3 more
    // requests (1 pool-queued + 1 batcher-held + 1 admitted) and then the
    // queue stays full for good — no movement is possible until the gate
    // opens, so the saturation check below is race-free.
    let config = ServeConfig::default().max_delay_ms(600_000).workers(1).queue_cap(1);
    let handle = ServeHandle::spawn(Arc::new(runner), config).unwrap();

    let first = handle.submit(example(&shape, 0)).unwrap();
    assert!(
        wait_until(WAIT, || entered.load(Ordering::SeqCst) >= 1),
        "worker never picked up the first batch"
    );

    let mut accepted: Vec<Pending> = Vec::new();
    let deadline = Instant::now() + WAIT;
    while accepted.len() < 3 && Instant::now() < deadline {
        match handle.try_submit(&example(&shape, 100 + accepted.len())).unwrap() {
            Some(pending) => accepted.push(pending),
            None => thread::sleep(Duration::from_millis(2)),
        }
    }
    assert_eq!(accepted.len(), 3, "pipeline failed to absorb its bounded backlog");
    assert!(
        handle.try_submit(&example(&shape, 200)).unwrap().is_none(),
        "try_submit must report full once the bounded pipeline is saturated"
    );
    assert!(handle.stats().rejected >= 1);

    // A *blocking* submit now parks until the pipeline drains.
    let done = Arc::new(AtomicBool::new(false));
    let blocked = {
        let handle = handle.clone();
        let done = done.clone();
        let image = example(&shape, 999);
        thread::spawn(move || {
            let pending = handle.submit(image).unwrap();
            done.store(true, Ordering::SeqCst);
            pending.wait()
        })
    };
    thread::sleep(Duration::from_millis(150));
    assert!(!done.load(Ordering::SeqCst), "submit returned despite a full queue");

    gate.release();
    let reply = first.wait_timeout(WAIT).unwrap().expect("first reply");
    assert_eq!(reply.stats.batch_fill, 1);
    for pending in accepted {
        pending.wait_timeout(WAIT).unwrap().expect("accepted reply");
    }
    let blocked_reply = blocked.join().expect("blocked submitter thread");
    assert!(done.load(Ordering::SeqCst), "blocking submit never unparked");
    blocked_reply.expect("blocked request must still be served");
    handle.shutdown().unwrap();
}

#[test]
fn shutdown_drains_in_flight_requests() {
    let shape = [2, 2];
    let runner = Arc::new(TestRunner::new(4, &shape, 3));
    // Deadline far away: only the shutdown drain can flush the partial
    // batch in test time.
    let config = ServeConfig::default().max_delay_ms(600_000).workers(2).queue_cap(64);
    let handle = ServeHandle::spawn(runner, config).unwrap();
    let pendings: Vec<Pending> =
        (0..3).map(|i| handle.submit(example(&shape, i)).unwrap()).collect();
    let t0 = Instant::now();
    let report = handle.shutdown().unwrap();
    assert!(t0.elapsed() < Duration::from_secs(60), "shutdown waited for the deadline");
    assert_eq!(report.requests, 3);
    assert_eq!(report.batches, 1);
    assert_eq!(report.drain_flushes, 1);
    for pending in pendings {
        let reply = pending.wait().expect("drained request must get a reply");
        assert_eq!(reply.stats.batch_fill, 3);
    }
    assert!(handle.submit(example(&shape, 9)).is_err(), "post-shutdown submit must error");
}

#[test]
fn merged_worker_ledger_traffic_equals_serial() {
    let shape = [2, 2];
    let (batch, n_batches) = (4usize, 6usize);
    let mut traffic = Vec::new();
    for workers in [1usize, 3] {
        let runner = Arc::new(TestRunner::new(batch, &shape, 3));
        let bytes_per_batch = runner.bytes_per_batch;
        let config = ServeConfig::default().max_delay_ms(600_000).workers(workers).queue_cap(64);
        let handle = ServeHandle::spawn(runner, config).unwrap();
        let pendings: Vec<Pending> = (0..batch * n_batches)
            .map(|i| handle.submit(example(&shape, i)).unwrap())
            .collect();
        for pending in pendings {
            pending.wait_timeout(WAIT).unwrap().expect("reply");
        }
        let report = handle.shutdown().unwrap();
        assert_eq!(report.batches, n_batches as u64, "workers={workers}");
        assert_eq!(
            report.memory.total_traffic(),
            (n_batches * bytes_per_batch) as u64,
            "workers={workers}"
        );
        assert_eq!(report.memory.unknown_frees(), 0, "workers={workers}");
        traffic.push(report.memory.total_traffic());
    }
    assert_eq!(traffic[0], traffic[1], "parallel ledger traffic diverged from serial");
}

#[test]
fn hot_swap_changes_subsequent_replies_without_drain() {
    let (b, h, c, k) = (2usize, 2usize, 3usize, 4usize);
    let runner = Arc::new(HostTailRunner::new(b, h, c, k));
    let shape = runner.example_shape();
    let handle = ServeHandle::spawn(runner, ServeConfig::default().workers(2)).unwrap();
    let ex = example(&shape, 5);
    let before =
        handle.submit(ex.clone()).unwrap().wait_timeout(WAIT).unwrap().expect("pre-swap reply");

    // Roll out a new head between batches: no drain, no restart.
    let w = Tensor::full(&[c, k], 0.5);
    let bias = Tensor::full(&[k], 0.25);
    handle.swap_params(Arc::new(vec![w.clone(), bias.clone()])).unwrap();
    let after =
        handle.submit(ex.clone()).unwrap().wait_timeout(WAIT).unwrap().expect("post-swap reply");

    // The post-swap reply must equal a direct run of the new head over
    // this example (row 0 of a zero-padded batch).
    let ex_len: usize = shape.iter().product();
    let mut stacked = Tensor::zeros(&[b, shape[0], shape[1], shape[2]]);
    stacked.data_mut()[..ex_len].copy_from_slice(ex.data());
    let expected = head_logits(&stacked, &w, &bias).unwrap();
    assert_eq!(after.logits.data(), &expected.data()[..k]);
    assert_ne!(before.logits.data(), after.logits.data(), "swap must change served values");
    let report = handle.shutdown().unwrap();
    assert_eq!(report.requests, 2);
}

#[test]
fn hot_swap_validates_shapes_and_unsupported_runners_reject() {
    let runner = Arc::new(HostTailRunner::new(2, 2, 3, 4));
    let handle = ServeHandle::spawn(runner, ServeConfig::default()).unwrap();
    // Wrong arity: the head is exactly [w (c, k), bias (k)].
    assert!(handle.swap_params(Arc::new(vec![Tensor::zeros(&[3, 4])])).is_err());
    // Wrong shapes.
    let bad = Arc::new(vec![Tensor::zeros(&[3, 5]), Tensor::zeros(&[5])]);
    assert!(handle.swap_params(bad).is_err());
    // Matching count + shapes succeeds.
    let good = Arc::new(vec![Tensor::zeros(&[3, 4]), Tensor::zeros(&[4])]);
    assert!(handle.swap_params(good).is_ok());
    handle.shutdown().unwrap();

    // TestRunner keeps the default implementation: hot-swap unsupported.
    let runner = Arc::new(TestRunner::new(2, &[2, 2], 3));
    let handle = ServeHandle::spawn(runner, ServeConfig::default()).unwrap();
    let err = handle.swap_params(Arc::new(Vec::new())).unwrap_err().to_string();
    assert!(err.contains("hot-swap"), "{err}");
    handle.shutdown().unwrap();
}

/// Artifact-gated: a checkpoint trained after the pipeline started rolls
/// out via `Session::push_params` and serves values bit-identical to
/// `predict_batches` over the stepped parameters.
#[test]
fn hot_swap_rollout_matches_predict_on_real_artifacts() {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    let engine = Engine::builder().artifacts("artifacts").build().unwrap();
    let cfg = engine.config().clone();
    let mut session = engine.session(SessionConfig::with_method("anode")).unwrap();
    let ds = SyntheticCifar::new(cfg.num_classes, 11, 0.1);
    let (train_imgs, train_labels) = ds.generate(cfg.batch, 0);
    let labels_f: Vec<f32> = train_labels.iter().map(|&l| l as f32).collect();
    let y = Tensor::from_vec(vec![cfg.batch], labels_f).unwrap();
    let (serve_imgs, _) = ds.generate(cfg.batch, 1);

    let config = ServeConfig::default().max_delay_ms(600_000).workers(2).queue_cap(256);
    let handle = session.serve(config).unwrap();
    // Train, then roll the new weights out without draining the queue.
    session.step(&train_imgs, &y).unwrap();
    session.push_params(&handle).unwrap();
    let expected = session.predict_batches_with_workers(&[serve_imgs.clone()], 1).unwrap();
    let pred = &expected.predictions[0];
    let k = *pred.logits.shape().last().unwrap();

    let pendings: Vec<Pending> = split_examples(&serve_imgs)
        .unwrap()
        .into_iter()
        .map(|ex| handle.submit(ex).unwrap())
        .collect();
    for (r, pending) in pendings.into_iter().enumerate() {
        let reply = pending.wait_timeout(Duration::from_secs(120)).unwrap().expect("reply");
        assert_eq!(reply.class, pred.classes[r], "request {r}");
        assert_eq!(reply.logits.data(), &pred.logits.data()[r * k..(r + 1) * k], "request {r}");
    }
    handle.shutdown().unwrap();
}

/// Artifact-gated: the serve path must be bit-identical to
/// `Session::predict_batches` on the real engine, and (on full batches)
/// meter the same ledger traffic.
#[test]
fn serve_matches_predict_batches_on_real_artifacts() {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    let engine = Engine::builder().artifacts("artifacts").build().unwrap();
    let cfg = engine.config().clone();
    let session = engine.session(SessionConfig::with_method("anode")).unwrap();
    let ds = SyntheticCifar::new(cfg.num_classes, 7, 0.1);
    let batches: Vec<Tensor> = (0..3).map(|b| ds.generate(cfg.batch, b as u64).0).collect();
    let expected = session.predict_batches_with_workers(&batches, 1).unwrap();

    for (workers, delay_ms, check_traffic) in
        [(1usize, 600_000u64, true), (2, 600_000, true), (2, 1, false)]
    {
        let config = ServeConfig::default().max_delay_ms(delay_ms).workers(workers).queue_cap(512);
        let handle = session.serve(config).unwrap();
        let mut pendings = Vec::new();
        for batch in &batches {
            for ex in split_examples(batch).unwrap() {
                pendings.push(handle.submit(ex).unwrap());
            }
        }
        let replies: Vec<_> = pendings
            .into_iter()
            .map(|p| p.wait_timeout(Duration::from_secs(120)).unwrap().expect("reply"))
            .collect();
        let report = handle.shutdown().unwrap();

        let mut idx = 0usize;
        for pred in &expected.predictions {
            let k = *pred.logits.shape().last().unwrap();
            for r in 0..cfg.batch {
                let reply = &replies[idx];
                assert_eq!(
                    reply.class, pred.classes[r],
                    "request {idx} workers={workers} delay={delay_ms}"
                );
                assert_eq!(
                    reply.logits.data(),
                    &pred.logits.data()[r * k..(r + 1) * k],
                    "request {idx} workers={workers} delay={delay_ms}"
                );
                idx += 1;
            }
        }
        if check_traffic {
            assert_eq!(
                report.memory.total_traffic(),
                expected.memory.total_traffic(),
                "serve ledger traffic diverged from the serial predict_batches ledger \
                 (workers={workers})"
            );
        }
    }
}
