//! Tests for the `anode::api` façade.
//!
//! Builder-validation tests run against synthetic manifests in a temp dir —
//! no compiled artifacts or PJRT backend needed (manifest validation is
//! eager, runtime creation is lazy). The serving-path tests require `make
//! artifacts` and skip gracefully when it hasn't run.

use std::path::{Path, PathBuf};

use anode::api::{make_eval_batches, Engine, SessionConfig, StrategyRegistry};
use anode::data::SyntheticCifar;
use anode::models::GradMethod;
use anode::tensor::Tensor;

// ---------------------------------------------------------------------------
// Strategy registry (pure)
// ---------------------------------------------------------------------------

#[test]
fn strategy_registry_round_trips_all_five_builtins() {
    let reg = StrategyRegistry::builtin();
    for spec in ["anode", "node", "otd", "anode-revolve4", "anode-equispaced2"] {
        let strategy = reg.create(spec).unwrap_or_else(|e| panic!("{spec}: {e}"));
        assert_eq!(strategy.name(), spec, "name round-trip failed for {spec}");
    }
    // The CLI enum and the registry agree on naming.
    for method in [
        GradMethod::Anode,
        GradMethod::Node,
        GradMethod::Otd,
        GradMethod::AnodeRevolve(7),
        GradMethod::AnodeEquispaced(3),
    ] {
        assert_eq!(reg.create_from_method(method).unwrap().name(), method.name());
    }
}

#[test]
fn strategy_registry_rejects_degenerate_and_unknown() {
    let reg = StrategyRegistry::builtin();
    assert!(reg.create("anode-revolve0").is_err());
    assert!(reg.create("anode-equispaced0").is_err());
    let err = reg.create("no-such-method").unwrap_err().to_string();
    assert!(err.contains("unknown gradient method"), "{err}");
}

// ---------------------------------------------------------------------------
// Builder validation against synthetic manifests
// ---------------------------------------------------------------------------

/// Write a manifest with a full resnet10 param layout, a valid config
/// section, and the given modules JSON fragment. Returns the temp dir.
fn fake_manifest_dir(tag: &str, modules_json: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("anode_api_test_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let mut params = String::new();
    let mut push = |name: &str| {
        if !params.is_empty() {
            params.push(',');
        }
        params.push_str(&format!(r#"{{"name":"{name}","shape":[1],"offset":0}}"#));
    };
    push("stem.w");
    push("stem.b");
    for s in 0..3 {
        for b in 0..2 {
            for leaf in ["w1", "b1", "w2", "b2"] {
                push(&format!("s{s}.b{b}.{leaf}"));
            }
        }
        if s < 2 {
            push(&format!("trans{s}.w"));
            push(&format!("trans{s}.b"));
        }
    }
    push("head.w");
    push("head.b");

    let manifest = format!(
        r#"{{
  "modules": [{modules_json}],
  "params": {{"resnet10": [{params}]}},
  "config": {{"batch": 32, "image": 32, "blocks_per_stage": 2, "nt": 4,
              "channels": [16, 32, 64]}}
}}"#
    );
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    dir
}

#[test]
fn builder_reports_missing_module_eagerly() {
    let dir = fake_manifest_dir("missing_module", "");
    let err = Engine::builder().artifacts(&dir).build().unwrap_err().to_string();
    assert!(err.contains("stem_fwd"), "error should name the missing module: {err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn builder_reports_missing_params_key() {
    let dir = fake_manifest_dir("bad_params_key", "");
    // Manifest only carries resnet10 params; ask for 100 classes.
    let err = Engine::builder()
        .artifacts(&dir)
        .classes(100)
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("resnet100"), "error should name the params key: {err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn builder_reports_unreadable_manifest() {
    let err = Engine::builder()
        .artifacts("/nonexistent/anode-test-dir")
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("manifest.json"), "{err}");
}

// ---------------------------------------------------------------------------
// Serving path (requires `make artifacts`)
// ---------------------------------------------------------------------------

fn real_engine() -> Option<Engine> {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Engine::builder().artifacts("artifacts").build().unwrap())
}

#[test]
fn predict_checks_batch_shape() {
    let Some(engine) = real_engine() else { return };
    let session = engine.session(SessionConfig::default()).unwrap();
    let cfg = engine.config().clone();

    // Wrong batch dimension: typed error before any module executes.
    let bad = Tensor::zeros(&[cfg.batch + 1, cfg.image, cfg.image, 3]);
    let err = session.predict(&bad).unwrap_err().to_string();
    assert!(err.contains("does not match"), "{err}");

    // Wrong rank.
    let bad2 = Tensor::zeros(&[cfg.batch, cfg.image * cfg.image * 3]);
    assert!(session.predict(&bad2).is_err());

    // Correct shape: classes + logits + stats come back.
    let ds = SyntheticCifar::new(cfg.num_classes, 42, 0.1);
    let (imgs, _) = ds.generate(cfg.batch, 0);
    let p = session.predict(&imgs).unwrap();
    assert_eq!(p.classes.len(), cfg.batch);
    assert_eq!(p.logits.shape(), &[cfg.batch, cfg.num_classes]);
    assert!(p.classes.iter().all(|&c| c < cfg.num_classes));
    assert!(p.logits.all_finite());
    assert!(p.stats.seconds > 0.0);
    assert!(p.stats.peak_activation_bytes > 0);
}

#[test]
fn session_trains_evaluates_and_serves() {
    let Some(engine) = real_engine() else { return };
    let mut session = engine.session(SessionConfig::with_method("anode")).unwrap();
    let cfg = engine.config().clone();

    let ds = SyntheticCifar::new(cfg.num_classes, 11, 0.1);
    let (imgs, labels) = ds.generate(cfg.batch, 0);
    let y = Tensor::from_vec(vec![cfg.batch], labels.iter().map(|&l| l as f32).collect()).unwrap();

    let s = session.step(&imgs, &y).unwrap();
    assert!(s.finite && s.loss.is_finite() && s.grad_norm > 0.0);
    assert_eq!(session.steps_taken(), 1);

    let (timgs, tlabels) = ds.generate(cfg.batch * 2, 1);
    let eval = make_eval_batches(&timgs, &tlabels, cfg.batch, 2);
    let e = session.evaluate(&eval).unwrap();
    assert!(e.loss.is_finite() && (0.0..=1.0).contains(&e.accuracy));

    let p = session.predict(&imgs).unwrap();
    assert_eq!(p.classes.len(), cfg.batch);
}

#[test]
fn gradcheck_confirms_checkpointed_strategies_match_dto() {
    let Some(engine) = real_engine() else { return };
    let cfg = engine.config().clone();
    let ds = SyntheticCifar::new(cfg.num_classes, 13, 0.1);
    let (imgs, labels) = ds.generate(cfg.batch, 0);
    let y = Tensor::from_vec(vec![cfg.batch], labels.iter().map(|&l| l as f32).collect()).unwrap();

    let mut session = engine.session(SessionConfig::with_method("anode-revolve2")).unwrap();
    let report = session.gradcheck(&imgs, &y).unwrap();
    assert_eq!(report.method, "anode-revolve2");
    assert_eq!(report.reference, "anode");
    assert!(report.loss_gap < 1e-5, "loss gap {}", report.loss_gap);
    assert!(report.max_rel_err < 2e-4, "revolve deviates: {}", report.max_rel_err);

    // The [8] method must NOT match DTO (§III) — gradcheck detects it.
    let mut node_session = engine.session(SessionConfig::with_method("node")).unwrap();
    let node_report = node_session.gradcheck(&imgs, &y).unwrap();
    assert!(
        node_report.max_rel_err > 1e-3,
        "node gradient suspiciously equal to DTO: {}",
        node_report.max_rel_err
    );
}

#[test]
fn session_fails_fast_when_strategy_kind_missing_from_manifest() {
    // A manifest with the full forward surface but no vjp/step/node/otd
    // artifacts: the engine builds, but any gradient strategy demanding a
    // missing kind must fail at session creation with a typed error.
    let mut modules = String::new();
    for name in [
        "stem_fwd",
        "stem_vjp",
        "trans0_fwd",
        "trans0_vjp",
        "trans1_fwd",
        "trans1_vjp",
        "head10_loss_grad",
        "head10_eval",
        "block_resnet_s0_euler_fwd",
        "block_resnet_s1_euler_fwd",
        "block_resnet_s2_euler_fwd",
    ] {
        if !modules.is_empty() {
            modules.push(',');
        }
        modules.push_str(&format!(
            r#"{{"name":"{name}","file":"{name}.hlo.txt","inputs":[],"outputs":[]}}"#
        ));
    }
    let dir = fake_manifest_dir("missing_kind", &modules);
    let engine = Engine::builder().artifacts(&dir).build().unwrap();

    let err = engine
        .session(SessionConfig::with_method("anode-revolve2"))
        .unwrap_err()
        .to_string();
    assert!(err.contains("anode-revolve2"), "error should name the method: {err}");
    assert!(
        err.contains("step_fwd") || err.contains("step_vjp"),
        "error should name the missing kind: {err}"
    );
    // The fused and baseline methods are equally unavailable here.
    assert!(engine.session(SessionConfig::with_method("anode")).is_err());
    assert!(engine.session(SessionConfig::with_method("node")).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn custom_strategy_registers_against_real_manifest() {
    let Some(engine) = real_engine() else { return };
    // A custom strategy demanding a module kind the manifest never ships.
    struct Hungry;
    impl anode::api::GradientStrategy for Hungry {
        fn name(&self) -> String {
            "hungry".into()
        }
        fn required_kinds(&self) -> &'static [&'static str] {
            &["vjp", "step_fwd", "step_vjp", "node", "otd"]
        }
        fn block_backward(
            &self,
            _ctx: &anode::api::BlockContext<'_>,
            gz: Tensor,
            _grads: &mut [Tensor],
            _ledger: &mut anode::memory::MemoryLedger,
        ) -> anode::api::Result<Tensor> {
            Ok(gz)
        }
    }
    let mut engine = engine;
    engine.strategies_mut().register("hungry", |spec| {
        (spec == "hungry").then(|| Ok(Box::new(Hungry) as Box<dyn anode::api::GradientStrategy>))
    });
    // All five kinds exist in the real manifest, so this succeeds...
    assert!(engine.session(SessionConfig::with_method("hungry")).is_ok());
    // ...and unknown methods still fail with the registry's name list.
    let err = engine
        .session(SessionConfig::with_method("missing"))
        .err()
        .map(|e| e.to_string())
        .unwrap_or_default();
    assert!(err.contains("unknown gradient method"), "{err}");
}
