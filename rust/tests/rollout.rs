//! Integration tests for `anode::rollout` — the train→canary→promote/
//! rollback orchestrator over a live serve pipeline.
//!
//! Everything runs offline on the simulated-device harness
//! (`runtime::sim`), across the device grid and under whichever backend
//! `ANODE_BACKEND` selects (the CI `rollout-e2e` leg runs this file with
//! a 4-device compiled-backend topology). Covered:
//!
//! * promotion end-to-end: an improving trainer's candidates hot-swap
//!   into the pipeline, and what serves afterwards is **bitwise** the
//!   trainer's promoted parameters;
//! * rollback end-to-end: a fault-injected device (the
//!   `open_simulated_with_fault` registry) fails the canary training
//!   step, and serving returns **bitwise** to the last-good snapshot —
//!   with the pipeline never draining;
//! * gate hysteresis: candidates that pass but never accumulate the
//!   consecutive-pass streak leave serving untouched (the pure flapping
//!   state machine is unit-tested inside `anode::rollout` itself);
//! * promotion churn under concurrent wire clients: no reply is dropped,
//!   reordered, or shed while snapshots swap mid-traffic;
//! * the PR 8 stats fix: `ServeStats` (and the metrics text rendered
//!   from it) is one coherent snapshot even while swaps churn —
//!   `device_loads` never tears;
//! * drain → pause: a wire `Drain` frame raises the server flag that the
//!   orchestrator's `pause_on` watches, so a draining server never takes
//!   another promotion.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anode::api::{Engine, Session, SessionConfig};
use anode::net::metrics::scrape_value;
use anode::net::{ClientReply, NetClient, NetConfig};
use anode::rollout::{RolloutConfig, RolloutOrchestrator};
use anode::runtime::sim::{write_artifacts, SimSpec};
use anode::runtime::{sim_devices_env, ArtifactRegistry};
use anode::serve::{split_examples, ServeConfig, ServeHandle, SloClass};
use anode::tensor::Tensor;

/// Write the sim artifact set into a fresh temp dir.
fn sim_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("anode_rollout_{}_{tag}", std::process::id()));
    write_artifacts(&dir, &SimSpec::default()).unwrap();
    dir
}

/// Device counts under test: {1, 2} plus the CI topology when set.
fn device_grid() -> Vec<usize> {
    let mut grid = vec![1usize, 2];
    if let Some(n) = sim_devices_env() {
        if !grid.contains(&n) {
            grid.push(n);
        }
    }
    grid
}

fn sim_engine(dir: &std::path::Path, devices: usize) -> Engine {
    Engine::builder().artifacts(dir).devices(devices).simulate(true).build().unwrap()
}

/// Deterministic (images, labels) batches off the spec's shared
/// generators, offset by `seed` so train and held-out streams differ.
fn stream(spec: &SimSpec, n: usize, seed: usize) -> Vec<(Tensor, Tensor)> {
    (0..n).map(|k| (spec.image_batch(seed + k), spec.label_batch(seed + k))).collect()
}

fn param_bits(params: &[Tensor]) -> Vec<u32> {
    params.iter().flat_map(|p| p.data().iter().map(|x| x.to_bits())).collect()
}

/// A serve pipeline that only flushes full batches (far deadline): with
/// ordered single-threaded submission the batcher reassembles exactly
/// the original batch tensors, so replies compare bitwise against the
/// predict path (the same idiom rust/tests/net.rs phase 1 locks in).
fn far_deadline() -> ServeConfig {
    ServeConfig::default().max_delay_ms(600_000).workers(2).queue_cap(512)
}

/// Submit every example of `images` in order and collect the
/// (class, logits) rows the pipeline answers with.
fn serve_rows(handle: &ServeHandle, images: &[Tensor]) -> Vec<(usize, Vec<f32>)> {
    let examples: Vec<Tensor> = images.iter().flat_map(|b| split_examples(b).unwrap()).collect();
    let pendings: Vec<_> = examples.iter().map(|ex| handle.submit(ex.clone()).unwrap()).collect();
    pendings
        .into_iter()
        .map(|p| {
            let reply = p.wait().unwrap();
            (reply.class, reply.logits.data().to_vec())
        })
        .collect()
}

/// The reference rows: `predict_batches` over the same batches with the
/// session's current parameters.
fn predict_rows(session: &Session, images: &[Tensor]) -> Vec<(usize, Vec<f32>)> {
    let pred = session.predict_batches_with_workers(images, 1).unwrap();
    let mut rows = Vec::new();
    for p in &pred.predictions {
        let k = *p.logits.shape().last().unwrap();
        for (r, &class) in p.classes.iter().enumerate() {
            rows.push((class, p.logits.data()[r * k..(r + 1) * k].to_vec()));
        }
    }
    rows
}

/// Promotion end-to-end across the device grid: two one-round campaigns
/// through the same long-lived orchestrator. Each promotes, the
/// live/last-good bookkeeping advances exactly one snapshot per
/// promotion, and the pipeline serves the trainer's latest parameters
/// bitwise — all through `promote_params` hot-swaps, zero drain.
#[test]
fn promotion_campaigns_hot_swap_trained_params_bitwise() {
    let dir = sim_dir("promote");
    for devices in device_grid() {
        let engine = sim_engine(&dir, devices);
        let mut session = engine.session(SessionConfig::with_method("anode")).unwrap();
        let initial_bits = param_bits(session.params());
        let handle = session.serve(far_deadline()).unwrap();

        let spec = SimSpec::default();
        let train = stream(&spec, 3, 0);
        let eval = stream(&spec, 2, 100);
        let config = RolloutConfig::default().rounds(1).canary_every(2).gate_threshold(10.0);
        let mut orch = RolloutOrchestrator::new(
            handle.clone(),
            Arc::new(session.params().to_vec()),
            config,
        );

        let r1 = orch.run(&mut session, &train, &eval).unwrap();
        assert_eq!(r1.rounds_run, 1, "devices={devices}");
        assert_eq!(r1.candidates, 1, "devices={devices}");
        assert_eq!(r1.promotions, 1, "devices={devices}");
        assert_eq!(r1.rollbacks, 0, "devices={devices}");
        assert!(!r1.paused, "devices={devices}");
        assert_eq!(r1.promote_latency.len(), 1, "devices={devices}");
        assert!(r1.baseline_loss.is_finite(), "devices={devices}");
        let c1_bits = param_bits(&orch.live());
        assert_ne!(c1_bits, initial_bits, "training never moved the params");
        assert_eq!(c1_bits, param_bits(session.params()), "devices={devices}");
        assert_eq!(param_bits(&orch.last_good()), initial_bits, "devices={devices}");

        let r2 = orch.run(&mut session, &train, &eval).unwrap();
        assert_eq!(r2.promotions, 1, "devices={devices}");
        assert_eq!(param_bits(&orch.last_good()), c1_bits, "devices={devices}");
        assert_eq!(
            param_bits(&orch.live()),
            param_bits(session.params()),
            "devices={devices}"
        );

        let stats = handle.stats();
        assert_eq!(stats.rollout_candidates, 2, "devices={devices}");
        assert_eq!(stats.rollout_promotions, 2, "devices={devices}");
        assert_eq!(stats.rollout_rollbacks, 0, "devices={devices}");

        // What the pipeline serves now is bitwise the trainer's params.
        let images: Vec<Tensor> = (0..2).map(|k| spec.image_batch(500 + k)).collect();
        assert_eq!(serve_rows(&handle, &images), predict_rows(&session, &images), "d={devices}");
        handle.shutdown().unwrap();
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Rollback end-to-end: after a healthy campaign promotes once, a second
/// campaign over a fault-injected session (device 0's registry fails
/// every `stem_fwd` call) hits the regression path. The orchestrator
/// swaps the last-good snapshot back in; serving afterwards is bitwise
/// the pre-promotion parameters and the pipeline never drained.
#[test]
fn injected_device_fault_rolls_back_to_last_good_bitwise() {
    let dir = sim_dir("rollback");
    for devices in device_grid() {
        let engine = sim_engine(&dir, devices);
        let mut session = engine.session(SessionConfig::with_method("anode")).unwrap();
        let initial = Arc::new(session.params().to_vec());
        let initial_bits = param_bits(&initial);
        let handle = session.serve(far_deadline()).unwrap();

        let spec = SimSpec::default();
        let train = stream(&spec, 3, 0);
        let eval = stream(&spec, 2, 100);
        let config = RolloutConfig::default().rounds(1).canary_every(1).gate_threshold(10.0);
        let mut orch = RolloutOrchestrator::new(handle.clone(), initial.clone(), config);

        // Phase 1, healthy: one promotion (live = candidate, last-good =
        // the initial snapshot).
        let r1 = orch.run(&mut session, &train, &eval).unwrap();
        assert_eq!(r1.promotions, 1, "devices={devices}");
        assert_ne!(param_bits(&orch.live()), initial_bits, "devices={devices}");

        // Phase 2, regressed: the same orchestrator drives a session over
        // the fault-injected registry for the same artifacts — the canary
        // training step errors, which is a regression event.
        let reg = Arc::new(
            ArtifactRegistry::open_simulated_with_fault(&dir, 0, "stem_fwd").unwrap(),
        );
        let faulty_engine = Engine::builder().registry(reg).devices(devices).build().unwrap();
        let mut faulty = faulty_engine.session(SessionConfig::with_method("anode")).unwrap();
        let r2 = orch.run(&mut faulty, &train, &eval).unwrap();
        assert_eq!(r2.rollbacks, 1, "devices={devices}");
        assert_eq!(r2.promotions, 0, "devices={devices}");
        assert_eq!(r2.rollback_latency.len(), 1, "devices={devices}");
        assert_eq!(param_bits(&orch.live()), initial_bits, "rollback target is last-good");

        let stats = handle.stats();
        assert_eq!(stats.rollout_promotions, 1, "devices={devices}");
        assert_eq!(stats.rollout_rollbacks, 1, "devices={devices}");

        // Zero drain: the same pipeline keeps serving, and its replies
        // are bitwise the last-good (initial) parameters — verified via a
        // healthy session pinned to that snapshot.
        let verify_engine = sim_engine(&dir, devices);
        let mut verify = verify_engine.session(SessionConfig::with_method("anode")).unwrap();
        verify.params_mut().clone_from_slice(&initial);
        let images: Vec<Tensor> = (0..2).map(|k| spec.image_batch(700 + k)).collect();
        assert_eq!(serve_rows(&handle, &images), predict_rows(&verify, &images), "d={devices}");
        handle.shutdown().unwrap();
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Hysteresis end-to-end: with the consecutive-pass bar above the round
/// count, every candidate passes the threshold yet none promotes — the
/// pipeline still serves the initial snapshot bitwise after the campaign
/// (the flapping-resets-the-streak state machine is unit-tested in
/// `anode::rollout`).
#[test]
fn hysteresis_streak_short_of_the_bar_never_promotes() {
    let dir = sim_dir("hysteresis");
    let engine = sim_engine(&dir, 1);
    let mut session = engine.session(SessionConfig::with_method("anode")).unwrap();
    let handle = session.serve(far_deadline()).unwrap();

    let spec = SimSpec::default();
    let train = stream(&spec, 3, 0);
    let eval = stream(&spec, 2, 100);
    let config =
        RolloutConfig::default().rounds(3).canary_every(1).gate_threshold(10.0).hysteresis(5);
    let mut orch =
        RolloutOrchestrator::new(handle.clone(), Arc::new(session.params().to_vec()), config);
    let report = orch.run(&mut session, &train, &eval).unwrap();

    assert_eq!(report.rounds_run, 3);
    assert_eq!(report.candidates, 3);
    assert_eq!(report.promotions, 0, "the streak never reached the hysteresis bar");
    assert_eq!(report.rollbacks, 0);
    let stats = handle.stats();
    assert_eq!(stats.rollout_candidates, 3);
    assert_eq!(stats.rollout_promotions, 0);

    // Serving is untouched: a fresh session holds the initial params.
    let fresh = engine.session(SessionConfig::with_method("anode")).unwrap();
    let images: Vec<Tensor> = (0..2).map(|k| spec.image_batch(900 + k)).collect();
    assert_eq!(serve_rows(&handle, &images), predict_rows(&fresh, &images));
    handle.shutdown().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// The one-shot `Session::rollout` convenience wires the orchestrator up
/// over the session's current params and runs a campaign.
#[test]
fn session_rollout_convenience_promotes() {
    let dir = sim_dir("convenience");
    let engine = sim_engine(&dir, 1);
    let mut session = engine.session(SessionConfig::with_method("anode")).unwrap();
    let handle = session.serve(far_deadline()).unwrap();
    let spec = SimSpec::default();
    let report = session
        .rollout(
            &handle,
            &stream(&spec, 2, 0),
            &stream(&spec, 2, 100),
            RolloutConfig::default().rounds(1).canary_every(1).gate_threshold(10.0),
        )
        .unwrap();
    assert_eq!(report.promotions, 1);
    assert_eq!(handle.stats().rollout_promotions, 1);
    handle.shutdown().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Promotion churn under concurrent wire clients: while a background
/// thread hot-swaps snapshots as fast as it can, pipelined protocol
/// clients must see every reply — none dropped, none reordered (the
/// client asserts FIFO ids), none shed — and every logits row stays
/// well-formed whichever snapshot served it.
#[test]
fn promotion_churn_drops_no_replies_under_concurrent_net_clients() {
    let dir = sim_dir("churn");
    let engine = sim_engine(&dir, 2);
    let mut session = engine.session(SessionConfig::with_method("anode")).unwrap();
    let serve_cfg =
        ServeConfig::default().max_delay_ms(5).batch_delay_ms(20).workers(2).queue_cap(512);
    let server = session.serve_net(serve_cfg, NetConfig::default(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let handle = server.handle().clone();

    let spec = SimSpec::default();
    let num_classes = spec.num_classes;
    let examples: Vec<Tensor> =
        (0..3).flat_map(|b| split_examples(&spec.image_batch(b)).unwrap()).collect();

    // Two valid snapshots to flip between: the initial params and a
    // one-step-trained variant.
    let snap_a = Arc::new(session.params().to_vec());
    session.step(&spec.image_batch(0), &spec.label_batch(0)).unwrap();
    let snap_b = Arc::new(session.params().to_vec());

    let stop = Arc::new(AtomicBool::new(false));
    let churn = {
        let handle = handle.clone();
        let stop = stop.clone();
        let (a, b) = (snap_a.clone(), snap_b.clone());
        thread::spawn(move || {
            let mut swaps = 0u64;
            while !stop.load(Ordering::SeqCst) {
                let snap = if swaps % 2 == 0 { b.clone() } else { a.clone() };
                handle.promote_params(snap).unwrap();
                swaps += 1;
                thread::sleep(Duration::from_millis(1));
            }
            swaps
        })
    };

    let clients = 3usize;
    let rounds = 4usize;
    thread::scope(|s| {
        for c in 0..clients {
            let addr = addr.clone();
            let examples = &examples;
            s.spawn(move || {
                let mut client = NetClient::connect(&addr).unwrap();
                for round in 0..rounds {
                    let replies = client.pipeline(examples, SloClass::Interactive).unwrap();
                    assert_eq!(replies.len(), examples.len(), "client {c} round {round}");
                    for (i, reply) in replies.iter().enumerate() {
                        let ClientReply::Reply { class, logits, .. } = reply else {
                            panic!("client {c} round {round} request {i} shed mid-promotion");
                        };
                        assert!(*class < num_classes, "client {c} round {round} request {i}");
                        assert!(
                            logits.data().iter().all(|v| v.is_finite()),
                            "client {c} round {round} request {i}"
                        );
                    }
                }
            });
        }
    });
    stop.store(true, Ordering::SeqCst);
    let swaps = churn.join().unwrap();
    assert!(swaps >= 1, "the churn thread never swapped");

    let stats = handle.stats();
    assert_eq!(stats.rollout_promotions, swaps);
    let total = (clients * rounds * examples.len()) as u64;
    let report = server.shutdown().unwrap();
    assert_eq!(report.net.replies, total, "a promotion dropped or duplicated replies");
    assert_eq!(report.net.shed, 0);
    assert_eq!(report.serve.requests, total);
    std::fs::remove_dir_all(&dir).ok();
}

/// The PR 8 stats fix, regression-locked: `ServeStats` snapshots (and
/// the metrics text rendered from them) are taken under the swap lock,
/// so a scrape landing mid-swap can never observe a torn multi-device
/// view — `device_loads` always has one entry per device and the
/// pipeline never reads as closed while swaps churn.
#[test]
fn stats_snapshot_stays_coherent_while_swaps_churn() {
    let dir = sim_dir("coherent");
    let devices = 2usize;
    let engine = sim_engine(&dir, devices);
    let session = engine.session(SessionConfig::with_method("anode")).unwrap();
    let serve_cfg = ServeConfig::default().max_delay_ms(2).workers(2).queue_cap(512);
    let server = session.serve_net(serve_cfg, NetConfig::default(), "127.0.0.1:0").unwrap();
    let handle = server.handle().clone();

    let spec = SimSpec::default();
    let snap = Arc::new(session.params().to_vec());
    let stop = Arc::new(AtomicBool::new(false));
    let churn = {
        let handle = handle.clone();
        let stop = stop.clone();
        let snap = snap.clone();
        thread::spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                handle.promote_params(snap.clone()).unwrap();
            }
        })
    };

    let examples = split_examples(&spec.image_batch(0)).unwrap();
    for i in 0..100 {
        // Keep the routers busy so device loads actually move.
        if i % 10 == 0 {
            let pendings: Vec<_> =
                examples.iter().map(|ex| handle.submit(ex.clone()).unwrap()).collect();
            for p in pendings {
                p.wait().unwrap();
            }
        }
        let stats = handle.stats();
        assert_eq!(stats.device_loads.len(), devices, "iteration {i}: torn device snapshot");
        assert!(!stats.closed, "iteration {i}");
        let text = server.metrics_text();
        let load_lines = text.lines().filter(|l| l.starts_with("anode_device_load{")).count();
        assert_eq!(load_lines, devices, "iteration {i}: torn metrics render\n{text}");
        assert_eq!(scrape_value(&text, "closed"), Some(0), "iteration {i}");
    }
    stop.store(true, Ordering::SeqCst);
    churn.join().unwrap();
    assert!(handle.stats().rollout_promotions >= 1);
    server.shutdown().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Drain → pause: a wire `Drain` frame raises the server flag; an
/// orchestrator whose `pause_on` watches that flag stops before taking
/// (or promoting) another candidate, and says so in its report.
#[test]
fn drain_frame_pauses_rollout_promotion() {
    let dir = sim_dir("drain");
    let engine = sim_engine(&dir, 1);
    let mut session = engine.session(SessionConfig::with_method("anode")).unwrap();
    let serve_cfg = ServeConfig::default().max_delay_ms(5).workers(2).queue_cap(256);
    let server = session.serve_net(serve_cfg, NetConfig::default(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    let mut client = NetClient::connect(&addr).unwrap();
    client.drain().unwrap();
    assert!(server.drain_requested());

    let spec = SimSpec::default();
    let config = RolloutConfig::default()
        .rounds(3)
        .canary_every(1)
        .gate_threshold(10.0)
        .pause_on(server.drain_flag());
    let mut orch = RolloutOrchestrator::new(
        server.handle().clone(),
        Arc::new(session.params().to_vec()),
        config,
    );
    let report = orch.run(&mut session, &stream(&spec, 2, 0), &stream(&spec, 2, 100)).unwrap();
    assert!(report.paused, "the campaign must report the pause");
    assert_eq!(report.rounds_run, 0, "a drained server trains no canary");
    assert_eq!(report.promotions, 0);
    assert_eq!(server.handle().stats().rollout_promotions, 0);

    let text = client.metrics().unwrap();
    assert_eq!(scrape_value(&text, "net_drain_requests_total"), Some(1), "{text}");
    assert_eq!(scrape_value(&text, "rollout_promotions_total"), Some(0), "{text}");
    server.shutdown().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}
