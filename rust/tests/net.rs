//! Integration tests for `anode::net` — the socket front end.
//!
//! Everything here runs offline: the wire tests need only loopback TCP,
//! the serving tests drive either the deterministic host-side runner or
//! the simulated-device engine (`runtime::sim`). Covered:
//!
//! * property-style round-trip of every frame type under randomized
//!   contents (hand-rolled forall on `anode::rng` — no external crates),
//!   including byte-at-a-time incremental decode;
//! * rejection without panic: truncated, bit-flipped, and garbage
//!   buffers must produce `Ok(None)` or a typed error, never unwind;
//! * loopback end-to-end on sim devices: N client threads × D devices,
//!   replies order-correct per connection and bit-identical to
//!   `Session::predict_batches`;
//! * load shedding over the wire: a saturated queue answers `RetryAfter`
//!   and a later retry succeeds;
//! * graceful drain: shutdown with replies still gated loses no
//!   accepted request;
//! * the metrics endpoint, over both the binary frame and HTTP/1.0.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anode::api::{argmax_rows, Engine, Prediction, PredictStats, SessionConfig};
use anode::memory::{Category, MemoryLedger};
use anode::net::metrics::scrape_value;
use anode::net::proto::{self, Frame, ProtoError};
use anode::net::{ClientReply, NetClient, NetConfig, NetServer};
use anode::rng::Rng;
use anode::runtime::sim::{write_artifacts, SimSpec};
use anode::runtime::Result;
use anode::serve::{split_examples, BatchRunner, ServeConfig, ServeHandle, SloClass};
use anode::tensor::Tensor;

const WAIT: Duration = Duration::from_secs(20);

// ---------------------------------------------------------------- proto

fn random_tensor(rng: &mut Rng) -> Tensor {
    let rank = 1 + rng.below(3);
    let dims: Vec<usize> = (0..rank).map(|_| 1 + rng.below(4)).collect();
    let len: usize = dims.iter().product();
    let data: Vec<f32> = (0..len)
        .map(|_| {
            // Exercise odd bit patterns, not just tame values.
            match rng.below(8) {
                0 => 0.0,
                1 => -0.0,
                2 => f32::MIN_POSITIVE / 2.0,
                3 => f32::MAX,
                _ => rng.uniform_range(-1e6, 1e6),
            }
        })
        .collect();
    Tensor::from_vec(dims, data).unwrap()
}

fn random_text(rng: &mut Rng) -> String {
    let len = rng.below(64);
    (0..len)
        .map(|_| char::from_u32(0x20 + rng.below(0x7e - 0x20) as u32).unwrap())
        .collect()
}

fn random_frame(rng: &mut Rng) -> Frame {
    let id = rng.next_u64();
    match rng.below(7) {
        0 => Frame::Request {
            id,
            class: if rng.below(2) == 0 { SloClass::Interactive } else { SloClass::Batch },
            image: random_tensor(rng),
        },
        1 => Frame::Reply {
            id,
            class: rng.next_u64() as u32,
            queue_wait_us: rng.next_u64(),
            execute_us: rng.next_u64(),
            batch_fill: rng.next_u64() as u32,
            batch_size: rng.next_u64() as u32,
            logits: random_tensor(rng),
        },
        2 => Frame::Error { id, message: random_text(rng) },
        3 => Frame::RetryAfter { id, retry_after_us: rng.next_u64() },
        4 => Frame::MetricsRequest { id },
        5 => Frame::Drain { id },
        _ => Frame::MetricsReply { id, text: random_text(rng) },
    }
}

/// Hand-rolled forall: every frame type round-trips bit-exactly through
/// encode → decode, including when the bytes arrive one at a time.
#[test]
fn random_frames_round_trip_whole_and_incrementally() {
    let mut rng = Rng::new(0xF0CACC1A);
    for case in 0..200 {
        let frame = random_frame(&mut rng);
        let bytes = frame.encode_vec();
        let (decoded, consumed) =
            proto::decode(&bytes).expect("valid frame").expect("complete frame");
        assert_eq!(consumed, bytes.len(), "case {case}");
        assert_eq!(decoded, frame, "case {case}");

        // Incremental: a decoder fed a growing prefix must answer
        // "need more" at every cut, then decode at the full length.
        let step = 1 + rng.below(7);
        let mut cut = 0usize;
        while cut < bytes.len() {
            assert_eq!(proto::decode(&bytes[..cut]).expect("prefix"), None, "case {case}");
            cut = (cut + step).min(bytes.len());
        }
        let (decoded, _) = proto::decode(&bytes).expect("full").expect("frame");
        assert_eq!(decoded, frame, "case {case}");
    }
}

/// Two frames back-to-back decode in sequence with exact consumed counts
/// (the reactor's read buffer sees exactly this).
#[test]
fn decode_consumes_frames_in_sequence() {
    let mut rng = Rng::new(7);
    let a = random_frame(&mut rng);
    let b = random_frame(&mut rng);
    let mut buf = a.encode_vec();
    let a_len = buf.len();
    b.encode(&mut buf);
    let (first, n1) = proto::decode(&buf).unwrap().unwrap();
    assert_eq!(first, a);
    assert_eq!(n1, a_len);
    let (second, n2) = proto::decode(&buf[n1..]).unwrap().unwrap();
    assert_eq!(second, b);
    assert_eq!(n1 + n2, buf.len());
}

/// Corrupted, truncated, and garbage buffers must never panic: every
/// outcome is `Ok(None)`, `Ok(Some(_))` (a flip that kept the frame
/// valid), or a typed `ProtoError`.
#[test]
fn corruption_never_panics() {
    let mut rng = Rng::new(0xBAD5EED);
    for _ in 0..100 {
        let frame = random_frame(&mut rng);
        let bytes = frame.encode_vec();
        // Single-byte corruption at every position.
        for pos in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[pos] ^= 1 << rng.below(8);
            let _ = proto::decode(&bad);
        }
        // Random truncation of a corrupted buffer.
        let mut bad = bytes.clone();
        let pos = rng.below(bad.len());
        bad[pos] = rng.next_u64() as u8;
        bad.truncate(rng.below(bad.len() + 1));
        let _ = proto::decode(&bad);
    }
    // Pure garbage of random lengths.
    for _ in 0..200 {
        let len = rng.below(256);
        let junk: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let _ = proto::decode(&junk);
    }
}

#[test]
fn oversized_and_malformed_are_typed_rejections() {
    // Declared payload over the cap.
    let mut bytes = Frame::MetricsRequest { id: 1 }.encode_vec();
    bytes[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(matches!(proto::decode(&bytes), Err(ProtoError::Oversized(_))));

    // A request whose tensor dims overflow the payload cap.
    let image = Tensor::from_vec(vec![2], vec![1.0, 2.0]).unwrap();
    let mut bytes = Frame::Request { id: 2, class: SloClass::Interactive, image }.encode_vec();
    // dims[0] lives right after the header's 20 bytes + 4-byte rank.
    bytes[24..28].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(matches!(proto::decode(&bytes), Err(ProtoError::Malformed(_))));

    // Unknown SLO class tag on a request.
    let image = Tensor::from_vec(vec![1], vec![0.5]).unwrap();
    let mut bytes = Frame::Request { id: 3, class: SloClass::Batch, image }.encode_vec();
    bytes[6] = 9;
    assert!(matches!(proto::decode(&bytes), Err(ProtoError::BadClass(9))));
}

// ----------------------------------------------------- loopback serving

/// Manually released latch blocking the runner (same pattern as
/// rust/tests/serve.rs), so saturation and drain are deterministic.
struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn new() -> Arc<Gate> {
        Arc::new(Gate { open: Mutex::new(false), cv: Condvar::new() })
    }

    fn release(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn wait_open(&self) {
        let mut open = self.open.lock().unwrap();
        while !*open {
            open = self.cv.wait(open).unwrap();
        }
    }
}

/// Deterministic host-side model: row logits are a fixed linear function
/// of the row sum, so wire replies compare bitwise against direct runs.
struct TestRunner {
    batch: usize,
    shape: Vec<usize>,
    k: usize,
    gate: Option<Arc<Gate>>,
    entered: Arc<AtomicUsize>,
}

impl TestRunner {
    fn new(batch: usize, shape: &[usize], k: usize) -> Self {
        Self {
            batch,
            shape: shape.to_vec(),
            k,
            gate: None,
            entered: Arc::new(AtomicUsize::new(0)),
        }
    }
}

impl BatchRunner for TestRunner {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn example_shape(&self) -> Vec<usize> {
        self.shape.clone()
    }

    fn run(&self, images: &Tensor, ledger: &mut MemoryLedger) -> Result<Prediction> {
        self.entered.fetch_add(1, Ordering::SeqCst);
        if let Some(gate) = &self.gate {
            gate.wait_open();
        }
        let id = ledger.alloc(64, Category::Transient);
        let ex_len: usize = self.shape.iter().product();
        let mut out = Vec::with_capacity(self.batch * self.k);
        for row in images.data().chunks(ex_len) {
            let s: f32 = row.iter().sum();
            out.extend((0..self.k).map(|j| s * (j as f32 + 1.0) - j as f32));
        }
        ledger.free(id);
        let logits = Tensor::from_vec(vec![self.batch, self.k], out).unwrap();
        let classes = argmax_rows(&logits);
        Ok(Prediction {
            classes,
            logits,
            stats: PredictStats {
                batch: self.batch,
                seconds: 0.0,
                examples_per_sec: 0.0,
                peak_activation_bytes: 64,
            },
        })
    }
}

fn example(shape: &[usize], seed: usize) -> Tensor {
    let len: usize = shape.iter().product();
    let data = (0..len).map(|j| ((seed * 31 + j) as f32) * 0.01 - 1.0).collect();
    Tensor::from_vec(shape.to_vec(), data).unwrap()
}

fn spawn_net(runner: TestRunner, config: ServeConfig, net: NetConfig) -> NetServer {
    let handle = ServeHandle::spawn(Arc::new(runner), config).unwrap();
    NetServer::bind(handle, "127.0.0.1:0", net).unwrap()
}

/// Per-connection FIFO over the wire: pipelined requests come back in
/// submission order with matching ids and bit-identical values, from
/// several client threads at once.
#[test]
fn loopback_replies_are_order_correct_across_client_threads() {
    let shape = [2, 3];
    let (batch, k, clients, per_client) = (4usize, 3usize, 4usize, 12usize);
    let reference = TestRunner::new(batch, &shape, k);
    let config = ServeConfig::default().max_delay_ms(2).workers(2).queue_cap(256);
    let server = spawn_net(TestRunner::new(batch, &shape, k), config, NetConfig::default());
    let addr = server.local_addr().to_string();

    thread::scope(|s| {
        for c in 0..clients {
            let addr = addr.clone();
            let reference = &reference;
            s.spawn(move || {
                let examples: Vec<Tensor> =
                    (0..per_client).map(|i| example(&shape, c * 1000 + i)).collect();
                let mut client = NetClient::connect(&addr).unwrap();
                let replies = client.pipeline(&examples, SloClass::Interactive).unwrap();
                assert_eq!(replies.len(), per_client);
                let mut ledger = MemoryLedger::new();
                for (i, (ex, reply)) in examples.iter().zip(&replies).enumerate() {
                    let ClientReply::Reply { class, logits, .. } = reply else {
                        panic!("client {c} request {i}: unexpected shed");
                    };
                    // Expected: this example as row 0 of a padded batch.
                    let ex_len: usize = shape.iter().product();
                    let mut stacked = Tensor::zeros(&[batch, shape[0], shape[1]]);
                    stacked.data_mut()[..ex_len].copy_from_slice(ex.data());
                    let pred = reference.run(&stacked, &mut ledger).unwrap();
                    assert_eq!(*class, pred.classes[0], "client {c} request {i}");
                    assert_eq!(
                        logits.data(),
                        &pred.logits.data()[..k],
                        "client {c} request {i}"
                    );
                }
            });
        }
    });

    let report = server.shutdown().unwrap();
    assert_eq!(report.net.replies, (clients * per_client) as u64);
    assert_eq!(report.net.connections, clients as u64);
    assert_eq!(report.net.shed, 0);
    assert_eq!(report.serve.requests, (clients * per_client) as u64);
}

/// Saturating the admission queue over the wire answers typed
/// `RetryAfter` (the request is NOT accepted), and retrying after the
/// gate opens succeeds.
#[test]
fn shed_returns_retry_after_and_retry_succeeds() {
    let shape = [2, 2];
    let gate = Gate::new();
    let mut runner = TestRunner::new(1, &shape, 3);
    runner.gate = Some(gate.clone());
    // batch=1, workers=1, queue_cap=1 with a gated runner: the pipeline
    // holds at most 4 requests (1 executing + 1 pool-queued + 1
    // batcher-held + 1 admitted), so 8 pipelined requests must shed.
    let config = ServeConfig::default().max_delay_ms(600_000).workers(1).queue_cap(1);
    let server = spawn_net(runner, config, NetConfig::default());
    let addr = server.local_addr().to_string();
    let handle = server.handle().clone();

    let worker = {
        let addr = addr.clone();
        thread::spawn(move || {
            let mut client = NetClient::connect(&addr).unwrap();
            // More than the pipeline can hold: the tail must shed. All
            // responses still arrive in request order.
            let examples: Vec<Tensor> = (0..8).map(|i| example(&shape, i)).collect();
            let replies = client.pipeline(&examples, SloClass::Interactive).unwrap();
            let shed: Vec<bool> =
                replies.iter().map(|r| matches!(r, ClientReply::RetryAfter(_))).collect();
            assert!(!shed[0], "the first request into an empty queue must be accepted");
            assert!(shed.iter().any(|&s| s), "queue never shed: {shed:?}");
            for (reply, &s) in replies.iter().zip(&shed) {
                if s {
                    let ClientReply::RetryAfter(hint) = reply else { unreachable!() };
                    assert!(*hint > Duration::ZERO, "shed must carry a retry hint");
                }
            }
            // Retry the shed requests now that the gate is open and the
            // pipeline drains.
            for (i, ex) in examples.iter().enumerate() {
                if !shed[i] {
                    continue;
                }
                let reply = client.request_with_retry(ex, SloClass::Interactive, 64).unwrap();
                assert!(
                    matches!(reply, ClientReply::Reply { .. }),
                    "request {i} still shed after retries"
                );
            }
        })
    };
    // The worker is blocked reading reply 1 (gated). Open the gate once
    // the saturated tail has been shed, so every queued response flushes
    // and the retries land in a draining pipeline.
    let t0 = Instant::now();
    while handle.stats().rejected < 1 {
        assert!(t0.elapsed() < WAIT, "queue never saturated");
        thread::sleep(Duration::from_millis(2));
    }
    gate.release();
    worker.join().unwrap();

    assert!(handle.stats().rejected >= 1, "serve layer never counted a shed");
    let report = server.shutdown().unwrap();
    assert!(report.net.shed >= 1, "reactor never counted a shed");
}

/// Graceful drain: shutdown while replies are still gated must flush
/// every accepted request before closing — no accepted request is lost.
#[test]
fn graceful_drain_loses_no_accepted_request() {
    let shape = [2, 2];
    let gate = Gate::new();
    let mut runner = TestRunner::new(2, &shape, 3);
    runner.gate = Some(gate.clone());
    let config = ServeConfig::default().max_delay_ms(600_000).workers(1).queue_cap(64);
    let server = spawn_net(runner, config, NetConfig::default());
    let addr = server.local_addr().to_string();
    let handle = server.handle().clone();
    let n = 5usize;

    let client_thread = {
        let addr = addr.clone();
        thread::spawn(move || {
            let mut client = NetClient::connect(&addr).unwrap();
            let examples: Vec<Tensor> = (0..n).map(|i| example(&shape, i)).collect();
            client.pipeline(&examples, SloClass::Interactive).unwrap()
        })
    };

    // Wait until all n are admitted (the client blocks reading replies).
    let t0 = Instant::now();
    while handle.stats().submitted < n as u64 {
        assert!(t0.elapsed() < WAIT, "requests never admitted");
        thread::sleep(Duration::from_millis(2));
    }

    // Start the drain with every reply still gated, then release.
    let shutdown_thread = thread::spawn(move || server.shutdown().unwrap());
    thread::sleep(Duration::from_millis(50));
    gate.release();

    let report = shutdown_thread.join().unwrap();
    let replies = client_thread.join().unwrap();
    assert_eq!(replies.len(), n, "drain lost accepted requests");
    assert!(
        replies.iter().all(|r| matches!(r, ClientReply::Reply { .. })),
        "an accepted request was not served: {replies:?}"
    );
    assert_eq!(report.net.replies, n as u64);
    assert_eq!(report.serve.requests, n as u64);
}

/// The metrics endpoint answers on both transports, with consistent
/// serve-layer counters.
#[test]
fn metrics_scrape_over_binary_frame_and_http() {
    let shape = [2, 2];
    let config = ServeConfig::default().max_delay_ms(2).workers(1).queue_cap(64);
    let server = spawn_net(TestRunner::new(2, &shape, 3), config, NetConfig::default());
    let addr = server.local_addr().to_string();

    let mut client = NetClient::connect(&addr).unwrap();
    for i in 0..4 {
        let reply = client.request(&example(&shape, i), SloClass::Batch).unwrap();
        assert!(matches!(reply, ClientReply::Reply { .. }));
    }
    let text = client.metrics().unwrap();
    assert_eq!(scrape_value(&text, "submitted_total"), Some(4), "{text}");
    assert_eq!(scrape_value(&text, "submitted_batch_total"), Some(4), "{text}");
    assert_eq!(scrape_value(&text, "completed_total"), Some(4), "{text}");
    assert_eq!(scrape_value(&text, "net_replies_total"), Some(4), "{text}");
    assert!(scrape_value(&text, "net_latency_p50_us").is_some(), "{text}");

    // Same listener, HTTP/1.0 text path.
    let mut http = TcpStream::connect(&addr).unwrap();
    http.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
    let mut response = String::new();
    http.read_to_string(&mut response).unwrap();
    assert!(response.starts_with("HTTP/1.0 200 OK\r\n"), "{response}");
    let body = response.split("\r\n\r\n").nth(1).expect("http body");
    assert_eq!(scrape_value(body, "submitted_total"), Some(4), "{body}");
    assert_eq!(scrape_value(body, "net_metrics_requests_total"), Some(1), "{body}");

    server.shutdown().unwrap();
}

/// The `Drain` admin frame (the std-only SIGTERM stand-in): the ack is
/// echoed back in FIFO order with the connection's other replies, the
/// server's drain flag latches for the owning driver, the scrapeable
/// counter ticks — and the reactor keeps serving (the flag only pauses
/// rollout promotion; shutdown stays with the driver).
#[test]
fn drain_frame_raises_flag_and_serving_continues() {
    let shape = [2, 2];
    let config = ServeConfig::default().max_delay_ms(2).workers(1).queue_cap(64);
    let server = spawn_net(TestRunner::new(2, &shape, 3), config, NetConfig::default());
    let addr = server.local_addr().to_string();
    assert!(!server.drain_requested(), "flag must start lowered");
    let flag = server.drain_flag();
    assert!(!flag.load(Ordering::SeqCst));

    let mut client = NetClient::connect(&addr).unwrap();
    let reply = client.request(&example(&shape, 0), SloClass::Interactive).unwrap();
    assert!(matches!(reply, ClientReply::Reply { .. }));
    client.drain().unwrap();
    assert!(server.drain_requested(), "drain ack arrived but the flag stayed low");
    assert!(flag.load(Ordering::SeqCst), "the shared flag handle must see the drain too");

    // The reactor records, it does not shut down: later requests (same
    // connection and fresh ones) still get served.
    let reply = client.request(&example(&shape, 1), SloClass::Interactive).unwrap();
    assert!(matches!(reply, ClientReply::Reply { .. }));
    let mut fresh = NetClient::connect(&addr).unwrap();
    let reply = fresh.request(&example(&shape, 2), SloClass::Interactive).unwrap();
    assert!(matches!(reply, ClientReply::Reply { .. }));

    let text = fresh.metrics().unwrap();
    assert_eq!(scrape_value(&text, "net_drain_requests_total"), Some(1), "{text}");

    let report = server.shutdown().unwrap();
    assert_eq!(report.net.drain_requests, 1);
    assert_eq!(report.net.replies, 3, "drain acks are not reply frames");
}

/// Garbage on the socket gets a typed error frame and a close — the
/// server neither panics nor hangs, and keeps serving other connections.
#[test]
fn garbage_connection_is_rejected_and_server_survives() {
    let shape = [2, 2];
    let config = ServeConfig::default().max_delay_ms(2).workers(1).queue_cap(64);
    let server = spawn_net(TestRunner::new(2, &shape, 3), config, NetConfig::default());
    let addr = server.local_addr().to_string();

    let mut bad = TcpStream::connect(&addr).unwrap();
    bad.write_all(b"definitely not the anode protocol\r\n").unwrap();
    let mut tail = Vec::new();
    // The server answers with an Error frame, then closes (EOF).
    bad.read_to_end(&mut tail).unwrap();
    let (frame, _) = proto::decode(&tail).expect("error frame").expect("complete");
    assert!(matches!(frame, Frame::Error { id: 0, .. }), "{frame:?}");

    // A well-behaved client still gets served afterwards.
    let mut client = NetClient::connect(&addr).unwrap();
    let reply = client.request(&example(&shape, 1), SloClass::Interactive).unwrap();
    assert!(matches!(reply, ClientReply::Reply { .. }));
    let report = server.shutdown().unwrap();
    assert!(report.net.protocol_errors >= 1);
}

// ------------------------------------------------- sim-device loopback

fn sim_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("anode_net_{}_{tag}", std::process::id()));
    write_artifacts(&dir, &SimSpec::default()).unwrap();
    dir
}

/// End-to-end on the simulated engine, two phases per device count:
///
/// 1. **Bit-identity** — one pipelined client submits every example in
///    order with the deadline far away, so the serve batcher reassembles
///    exactly the original full batches (the sim model digests the whole
///    batch tensor — the same caveat `serve_grid_matches_serial_predict`
///    documents) and every wire reply is bit-identical to
///    `Session::predict_batches`.
/// 2. **Concurrency** — three client threads with interleaved shares,
///    both SLO classes and the adaptive window live; batch composition
///    is nondeterministic here, so the assertions are structural:
///    nothing sheds, every reply is well-formed, and the per-class
///    admission counters and scraped metrics add up.
#[test]
fn loopback_serving_matches_predict_batches_on_sim_devices() {
    let dir = sim_dir("e2e");
    for devices in [1usize, 2] {
        let engine =
            Engine::builder().artifacts(&dir).devices(devices).simulate(true).build().unwrap();
        let cfg = engine.config().clone();
        let session = engine.session(SessionConfig::with_method("anode")).unwrap();
        let spec = SimSpec::default();
        let batches: Vec<Tensor> = (0..2).map(|b| spec.image_batch(b)).collect();
        let expected = session.predict_batches_with_workers(&batches, 1).unwrap();
        let examples: Vec<Tensor> =
            batches.iter().flat_map(|b| split_examples(b).unwrap()).collect();

        // Flatten the expected per-example answers in submission order.
        let mut expected_rows: Vec<(usize, Vec<f32>)> = Vec::new();
        for pred in &expected.predictions {
            let k = *pred.logits.shape().last().unwrap();
            for r in 0..cfg.batch {
                expected_rows
                    .push((pred.classes[r], pred.logits.data()[r * k..(r + 1) * k].to_vec()));
            }
        }

        // --- phase 1: single pipelined client, exact identity ----------
        let config = ServeConfig::default().max_delay_ms(600_000).workers(2).queue_cap(512);
        let net = NetConfig::default().inflight_window(examples.len().max(1));
        let server = session.serve_net(config, net, "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let mut client = NetClient::connect(&addr).unwrap();
        let replies = client.pipeline(&examples, SloClass::Interactive).unwrap();
        for (i, (reply, (want_class, want_logits))) in
            replies.iter().zip(&expected_rows).enumerate()
        {
            let ClientReply::Reply { class, logits, .. } = reply else {
                panic!("request {i} shed on devices={devices}");
            };
            assert_eq!(class, want_class, "request {i} devices={devices}");
            assert_eq!(logits.data(), want_logits.as_slice(), "request {i} devices={devices}");
        }
        drop(client);
        let report = server.shutdown().unwrap();
        assert_eq!(report.serve.requests, examples.len() as u64, "devices={devices}");
        assert_eq!(report.net.replies, examples.len() as u64, "devices={devices}");
        assert_eq!(report.serve.devices, devices, "devices={devices}");
        assert_eq!(report.serve.full_flushes, batches.len() as u64, "devices={devices}");

        // --- phase 2: concurrent clients, adaptive window, mixed SLO ---
        let config = ServeConfig::default()
            .max_delay_ms(5)
            .batch_delay_ms(20)
            .adaptive_delay_ms(1, 20)
            .workers(2)
            .queue_cap(512);
        let server = session.serve_net(config, NetConfig::default(), "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let clients = 3usize;
        let num_classes = cfg.num_classes;

        thread::scope(|s| {
            for c in 0..clients {
                let addr = addr.clone();
                let examples = &examples;
                s.spawn(move || {
                    let mut client = NetClient::connect(&addr).unwrap();
                    // Interleaved shares; client 1 runs batch-class so
                    // both deadline windows serve live traffic.
                    let mine: Vec<usize> = (c..examples.len()).step_by(clients).collect();
                    let class = if c == 1 { SloClass::Batch } else { SloClass::Interactive };
                    let share: Vec<Tensor> = mine.iter().map(|&i| examples[i].clone()).collect();
                    let replies = client.pipeline(&share, class).unwrap();
                    for (&i, reply) in mine.iter().zip(&replies) {
                        let ClientReply::Reply { class, logits, .. } = reply else {
                            panic!("request {i} shed on devices={devices}");
                        };
                        assert!(*class < num_classes, "request {i} devices={devices}");
                        assert_eq!(logits.data().len(), num_classes, "request {i}");
                        assert!(logits.data().iter().all(|v| v.is_finite()), "request {i}");
                    }
                });
            }
        });

        let text = NetClient::connect(&addr).and_then(|mut c| c.metrics()).unwrap();
        let expected_batch = (1..examples.len()).step_by(clients).count() as u64;
        assert_eq!(scrape_value(&text, "submitted_total"), Some(examples.len() as u64));
        assert_eq!(scrape_value(&text, "submitted_batch_total"), Some(expected_batch));
        assert_eq!(scrape_value(&text, "adaptive_delay"), Some(1), "{text}");
        let report = server.shutdown().unwrap();
        assert_eq!(report.serve.requests, examples.len() as u64, "devices={devices}");
        assert_eq!(report.net.replies, examples.len() as u64, "devices={devices}");
        assert_eq!(report.net.shed, 0, "devices={devices}");
    }
    std::fs::remove_dir_all(&dir).ok();
}
