//! Multi-device sharding tests on the **simulated-device harness**
//! (`runtime::sim` — deterministic value-level execution on the vendored
//! xla stub, no artifacts or backend needed; rust/DESIGN.md §6d).
//!
//! The lock-in grid: params/losses/logits must be **bit-identical to the
//! serial run** for every (devices × workers × gradient strategy)
//! combination across all three execution paths — training
//! (`step_accumulate`), prediction (`predict_batches`) and serving
//! (`serve`) — with ledger traffic equal to serial throughout. Plus:
//! ordering under a router forced into worst-case imbalance, and fault
//! injection (a panicking device runner / a registry-level device fault)
//! that must degrade to error replies / propagated errors without
//! deadlocking the healthy device pools.
//!
//! The device grid is {1, 2, 4} extended by `ANODE_SIM_DEVICES` when set
//! (the CI sim job runs the suite with a 4-device topology).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anode::api::{argmax_rows, Engine, Prediction, PredictStats, SessionConfig};
use anode::memory::{Category, MemoryLedger};
use anode::models::ModelConfig;
use anode::runtime::sim::{write_artifacts, SimSpec};
use anode::runtime::{sim_devices_env, ArtifactRegistry, Backend, Result};
use anode::serve::{BatchRunner, Pending, ServeConfig, ServeHandle};
use anode::tensor::Tensor;
use anode::util::pool::{sharded_map_with, PersistentPool, ShardRouter};

const WAIT: Duration = Duration::from_secs(20);
const STRATEGIES: [&str; 7] = [
    "anode",
    "node",
    "otd",
    "anode-revolve3",
    "anode-equispaced2",
    "symplectic",
    "interp-adjoint3",
];

/// Write the sim artifact set into a fresh temp dir.
fn sim_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("anode_shard_{}_{tag}", std::process::id()));
    write_artifacts(&dir, &SimSpec::default()).unwrap();
    dir
}

/// A simulated engine sharding over `devices` devices.
fn sim_engine(dir: &Path, devices: usize) -> Engine {
    Engine::builder().artifacts(dir).devices(devices).simulate(true).build().unwrap()
}

/// Device counts under test: {1, 2, 4} plus the CI topology when set.
fn device_grid() -> Vec<usize> {
    let mut grid = vec![1usize, 2, 4];
    if let Some(n) = sim_devices_env() {
        if !grid.contains(&n) {
            grid.push(n);
        }
    }
    grid
}

/// Deterministic image batch shaped for the sim model. Every test engine
/// here is built from `SimSpec::default()` artifacts, so the spec's
/// shared generators are the single source of input shapes (the
/// `shard_throughput` bench uses the same ones); the engine config is
/// taken only to assert the two cannot drift.
fn image(cfg: &ModelConfig, k: usize) -> Tensor {
    let spec = SimSpec::default();
    assert_eq!((cfg.batch, cfg.image), (spec.batch, spec.image), "engine/spec drift");
    spec.image_batch(k)
}

fn labels(cfg: &ModelConfig, k: usize) -> Tensor {
    let spec = SimSpec::default();
    assert_eq!(cfg.num_classes, spec.num_classes, "engine/spec drift");
    spec.label_batch(k)
}

fn micro_batches(cfg: &ModelConfig, accum: usize) -> Vec<(Tensor, Tensor)> {
    (0..accum).map(|m| (image(cfg, m), labels(cfg, m))).collect()
}

/// Train `steps` accumulate-steps from a fresh session; return per-step
/// loss bits, final param bits, and training-ledger traffic.
fn train_run(
    engine: &Engine,
    method: &str,
    workers: usize,
    steps: usize,
) -> (Vec<u32>, Vec<u32>, u64) {
    let cfg = engine.config().clone();
    let micro = micro_batches(&cfg, 4);
    let mut session = engine.session(SessionConfig::with_method(method)).unwrap();
    let traffic0 = session.memory().total_traffic();
    let mut losses = Vec::with_capacity(steps);
    for s in 0..steps {
        let stats = session.step_accumulate_with_workers(&micro, workers).unwrap();
        assert!(stats.finite, "{method} non-finite at step {s}");
        losses.push(stats.loss.to_bits());
    }
    let params: Vec<u32> =
        session.params().iter().flat_map(|p| p.data().iter().map(|x| x.to_bits())).collect();
    assert_eq!(session.memory().unknown_frees(), 0, "{method} workers={workers}");
    (losses, params, session.memory().total_traffic() - traffic0)
}

/// The lock-in grid for the training path: every (devices, workers,
/// strategy) combination must produce bitwise the serial params/losses
/// and meter exactly the serial ledger traffic.
#[test]
fn training_grid_bit_identical_to_serial_for_all_strategies() {
    let dir = sim_dir("train_grid");
    let engines: Vec<(usize, Engine)> =
        device_grid().into_iter().map(|d| (d, sim_engine(&dir, d))).collect();
    let serial = &engines[0].1;
    assert_eq!(serial.device_count(), 1);
    for method in STRATEGIES {
        let (loss_ref, params_ref, traffic_ref) = train_run(serial, method, 1, 2);
        for (devices, engine) in &engines {
            for workers in [1usize, 2, 4] {
                if *devices == 1 && workers == 1 {
                    continue;
                }
                let (loss, params, traffic) = train_run(engine, method, workers, 2);
                assert_eq!(
                    loss_ref, loss,
                    "{method}: losses diverged at devices={devices} workers={workers}"
                );
                assert_eq!(
                    params_ref, params,
                    "{method}: params diverged at devices={devices} workers={workers}"
                );
                assert_eq!(
                    traffic_ref, traffic,
                    "{method}: ledger traffic diverged at devices={devices} workers={workers}"
                );
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Adjoint-consistency lock-in: where a checkpoint schedule degenerates
/// to store-everything (budget m >= nt), the checkpointed adjoint runs
/// exactly the store-all action list the symplectic strategy always uses
/// — so per-step losses and final params must match **bitwise**, on both
/// execution backends. (Ledger traffic is deliberately not compared: the
/// strategies meter different StepState slot counts over the same action
/// list — nt+1 for symplectic's store-all vs m for the degenerate
/// budget.)
#[test]
fn symplectic_matches_degenerate_schedules_bitwise() {
    let dir = sim_dir("symplectic_consistency");
    for backend in [Backend::Sim, Backend::Compiled] {
        let engine = backend_engine(&dir, 1, backend);
        let (loss_ref, params_ref, _) = train_run(&engine, "symplectic", 1, 2);
        // SimSpec::default() runs nt = 4 steps, so a budget of 8 is past
        // the degenerate edge for both schedule families.
        for degenerate in ["anode-revolve8", "anode-equispaced8"] {
            let (loss, params, _) = train_run(&engine, degenerate, 1, 2);
            assert_eq!(loss_ref, loss, "{backend:?} {degenerate}: losses diverged");
            assert_eq!(params_ref, params, "{backend:?} {degenerate}: params diverged");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The prediction path across the grid: logits bit-identical to serial,
/// aggregate traffic equal to serial, per-device ledgers accounting for
/// every byte (the cross-device report is their additive-traffic /
/// max-peak fold).
#[test]
fn predict_grid_matches_serial_and_accounts_per_device() {
    let dir = sim_dir("predict_grid");
    let serial_engine = sim_engine(&dir, 1);
    let cfg = serial_engine.config().clone();
    let batches: Vec<Tensor> = (0..8).map(|k| image(&cfg, 100 + k)).collect();
    let serial_session = serial_engine.session(SessionConfig::with_method("anode")).unwrap();
    let serial = serial_session.predict_batches_with_workers(&batches, 1).unwrap();

    for devices in device_grid() {
        let engine = sim_engine(&dir, devices);
        let session = engine.session(SessionConfig::with_method("anode")).unwrap();
        for workers in [1usize, 2, 4] {
            let par = session.predict_batches_with_workers(&batches, workers).unwrap();
            assert_eq!(par.predictions.len(), serial.predictions.len());
            for (s, p) in serial.predictions.iter().zip(&par.predictions) {
                assert_eq!(s.classes, p.classes, "devices={devices} workers={workers}");
                assert_eq!(
                    s.logits.data(),
                    p.logits.data(),
                    "logits diverged at devices={devices} workers={workers}"
                );
            }
            assert_eq!(
                par.memory.total_traffic(),
                serial.memory.total_traffic(),
                "devices={devices} workers={workers}"
            );
            assert_eq!(par.memory.unknown_frees(), 0);
            assert_eq!(par.device_memory.len(), devices, "workers={workers}");
            let device_traffic: u64 = par.device_memory.iter().map(|l| l.total_traffic()).sum();
            assert_eq!(
                device_traffic,
                par.memory.total_traffic(),
                "per-device ledgers must account for every byte \
                 (devices={devices} workers={workers})"
            );
            // The cross-device peak is the max over devices, never a sum.
            let max_dev_peak =
                par.device_memory.iter().map(|l| l.peak_bytes()).max().unwrap_or(0);
            assert_eq!(
                par.memory.peak_bytes(),
                max_dev_peak,
                "devices={devices} workers={workers}"
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The serving path across the grid: one admission queue over one pool
/// per device, filled batches routed by load — replies bit-identical to
/// the serial `predict_batches` sweep for every (devices, workers)
/// combination. (The sim model digests the whole batch tensor, so the
/// identity holds exactly on full flushes — the test submits whole
/// batches and keeps the deadline far away, like the serve suite does.)
#[test]
fn serve_grid_matches_serial_predict() {
    let dir = sim_dir("serve_grid");
    let serial_engine = sim_engine(&dir, 1);
    let cfg = serial_engine.config().clone();
    let batches: Vec<Tensor> = (0..4).map(|k| image(&cfg, 200 + k)).collect();
    let serial_session = serial_engine.session(SessionConfig::with_method("anode")).unwrap();
    let expected = serial_session.predict_batches_with_workers(&batches, 1).unwrap();

    for devices in device_grid() {
        let engine = sim_engine(&dir, devices);
        let session = engine.session(SessionConfig::with_method("anode")).unwrap();
        for workers in [1usize, 2, 4] {
            let config =
                ServeConfig::default().max_delay_ms(600_000).workers(workers).queue_cap(256);
            let handle = session.serve(config).unwrap();
            assert_eq!(handle.device_count(), devices);
            let mut pendings: Vec<Pending> = Vec::new();
            for batch in &batches {
                for ex in anode::serve::split_examples(batch).unwrap() {
                    pendings.push(handle.submit(ex).unwrap());
                }
            }
            let mut idx = 0usize;
            for pred in &expected.predictions {
                let k = *pred.logits.shape().last().unwrap();
                for r in 0..cfg.batch {
                    let reply = pendings[idx]
                        .wait_timeout(WAIT)
                        .unwrap()
                        .expect("serve reply timed out");
                    assert_eq!(
                        reply.class, pred.classes[r],
                        "request {idx} devices={devices} workers={workers}"
                    );
                    assert_eq!(
                        reply.logits.data(),
                        &pred.logits.data()[r * k..(r + 1) * k],
                        "request {idx} devices={devices} workers={workers}"
                    );
                    idx += 1;
                }
            }
            let report = handle.shutdown().unwrap();
            assert_eq!(report.devices, devices);
            assert_eq!(report.per_device_memory.len(), devices);
            assert_eq!(report.requests, (batches.len() * cfg.batch) as u64);
            assert_eq!(
                report.memory.total_traffic(),
                expected.memory.total_traffic(),
                "serve ledger traffic diverged from serial predict \
                 (devices={devices} workers={workers})"
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Ordering under worst-case imbalance: with one device pinned under a
/// huge standing load, the router drains every chunk to the idle device —
/// and the output still comes back in exact input order. Once the load
/// lifts, chunks spread over both devices again, still in order.
#[test]
fn router_worst_case_imbalance_never_reorders_output() {
    let p0: PersistentPool = PersistentPool::new(2, "shard-imb0", || ()).unwrap();
    let p1: PersistentPool = PersistentPool::new(2, "shard-imb1", || ()).unwrap();
    let pools = [&p0, &p1];
    let router = ShardRouter::new(&[2, 2]);
    assert_eq!(router.acquire(1_000), 0, "first pick from idle must be device 0");

    let items: Vec<usize> = (0..37).collect();
    let want: Vec<usize> = items.iter().map(|&x| x * 3).collect();
    let count_and_triple = |_s: &mut (), c: &mut usize, i: usize, x: &usize| {
        assert_eq!(i, *x, "index must match input position");
        *c += 1;
        *x * 3
    };
    let (out, states) = sharded_map_with(&pools, &router, 2, &items, || 0usize, count_and_triple);
    assert_eq!(out, want, "imbalanced routing must not reorder output");
    assert!(
        states.iter().all(|(d, _)| *d == 1),
        "all chunks must drain to the idle device: {:?}",
        states.iter().map(|(d, c)| (*d, *c)).collect::<Vec<_>>()
    );
    assert_eq!(states.iter().map(|(_, c)| *c).sum::<usize>(), items.len());
    // The map's own load drained; the standing imbalance remains.
    assert_eq!(router.loads(), vec![1_000, 0]);

    router.complete(0, 1_000);
    let (out2, states2) = sharded_map_with(&pools, &router, 2, &items, || 0usize, count_and_triple);
    assert_eq!(out2, want, "balanced routing must not reorder output");
    let devices_used: std::collections::HashSet<usize> =
        states2.iter().map(|(d, _)| *d).collect();
    assert_eq!(devices_used.len(), 2, "balanced start must feed both devices");
    assert_eq!(router.loads(), vec![0, 0], "all load must drain after the map");
}

// ---------------------------------------------------------------------------
// Fault injection
// ---------------------------------------------------------------------------

/// Manually released latch blocking a runner, to hold one device busy
/// deterministically.
struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn new() -> Arc<Gate> {
        Arc::new(Gate { open: Mutex::new(false), cv: Condvar::new() })
    }

    fn release(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn wait_open(&self) {
        let mut open = self.open.lock().unwrap();
        while !*open {
            open = self.cv.wait(open).unwrap();
        }
    }
}

/// Healthy device runner: per-row linear logits, optionally gated.
struct RowRunner {
    batch: usize,
    shape: Vec<usize>,
    k: usize,
    gate: Option<Arc<Gate>>,
    entered: Arc<AtomicUsize>,
}

impl BatchRunner for RowRunner {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn example_shape(&self) -> Vec<usize> {
        self.shape.clone()
    }

    fn run(&self, images: &Tensor, ledger: &mut MemoryLedger) -> Result<Prediction> {
        self.entered.fetch_add(1, Ordering::SeqCst);
        if let Some(gate) = &self.gate {
            gate.wait_open();
        }
        let id = ledger.alloc(64, Category::Transient);
        let ex_len: usize = self.shape.iter().product();
        let mut out = Vec::with_capacity(self.batch * self.k);
        for row in images.data().chunks(ex_len) {
            let s: f32 = row.iter().sum();
            out.extend((0..self.k).map(|j| s * (j as f32 + 1.0) - j as f32));
        }
        ledger.free(id);
        let logits = Tensor::from_vec(vec![self.batch, self.k], out).unwrap();
        let classes = argmax_rows(&logits);
        Ok(Prediction {
            classes,
            logits,
            stats: PredictStats {
                batch: self.batch,
                seconds: 0.0,
                examples_per_sec: 0.0,
                peak_activation_bytes: 64,
            },
        })
    }
}

/// A device whose runner panics mid-batch — the serve-side fault model.
struct PanickingRunner {
    batch: usize,
    shape: Vec<usize>,
}

impl BatchRunner for PanickingRunner {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn example_shape(&self) -> Vec<usize> {
        self.shape.clone()
    }

    fn run(&self, _images: &Tensor, _ledger: &mut MemoryLedger) -> Result<Prediction> {
        panic!("simulated device blew up mid-batch");
    }
}

fn row_example(shape: &[usize], seed: usize) -> Tensor {
    let len: usize = shape.iter().product();
    let data = (0..len).map(|j| ((seed * 31 + j) as f32) * 0.01 - 1.0).collect();
    Tensor::from_vec(shape.to_vec(), data).unwrap()
}

/// Serve-side fault injection: device 1's runner panics mid-batch. Its
/// batches must become error replies; device 0 keeps serving; the
/// pipeline never deadlocks, keeps accepting work, and drains cleanly on
/// shutdown with every request answered.
#[test]
fn panicking_device_runner_yields_error_replies_without_deadlock() {
    let shape = vec![2usize, 2];
    let (batch, k) = (2usize, 3usize);
    let gate = Gate::new();
    let entered = Arc::new(AtomicUsize::new(0));
    let good = Arc::new(RowRunner {
        batch,
        shape: shape.clone(),
        k,
        gate: Some(gate.clone()),
        entered: entered.clone(),
    });
    let bad = Arc::new(PanickingRunner { batch, shape: shape.clone() });
    let config = ServeConfig::default().max_delay_ms(600_000).workers(1).queue_cap(64);
    let handle =
        ServeHandle::spawn_sharded(vec![good as Arc<dyn BatchRunner>, bad], config).unwrap();
    assert_eq!(handle.device_count(), 2);

    // Batch A fills and routes to idle device 0, whose gated runner holds
    // it (and its router load) open.
    let a: Vec<Pending> =
        (0..batch).map(|i| handle.submit(row_example(&shape, i)).unwrap()).collect();
    let deadline = std::time::Instant::now() + WAIT;
    while entered.load(Ordering::SeqCst) < 1 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(entered.load(Ordering::SeqCst) >= 1, "device 0 never picked up batch A");

    // Batch B must route to device 1 (least loaded) — whose runner
    // panics. Every request in it gets an error reply, not a hang.
    let b: Vec<Pending> =
        (0..batch).map(|i| handle.submit(row_example(&shape, 100 + i)).unwrap()).collect();
    for (i, pending) in b.into_iter().enumerate() {
        let err = pending
            .wait_timeout(WAIT)
            .map(|r| r.expect("reply timed out"))
            .expect_err(&format!("request {i} on the panicking device must error"));
        assert!(err.to_string().contains("panicked"), "unexpected error: {err}");
    }

    // The healthy device finishes untouched.
    gate.release();
    for pending in a {
        pending.wait_timeout(WAIT).unwrap().expect("healthy device reply");
    }

    // The pipeline is still alive: later submissions get replies (from
    // whichever device the router picks — a broken device answers with
    // errors, never silence), and shutdown drains with all 6 requests
    // completed.
    let c: Vec<Pending> =
        (0..batch).map(|i| handle.submit(row_example(&shape, 200 + i)).unwrap()).collect();
    for pending in c {
        let _ = pending.wait_timeout(WAIT).expect("pipeline deadlocked after device fault");
    }
    let report = handle.shutdown().unwrap();
    assert_eq!(report.requests, 3 * batch as u64);
    assert_eq!(report.devices, 2);
}

/// Session-side fault injection: device 0's registry fails every
/// `stem_fwd` call (the simulated broken device). Training and evaluation
/// must surface the typed error — no deadlock, no panic — and the session
/// (and its per-device pools) must stay usable and drain cleanly on drop.
#[test]
fn faulty_device_registry_propagates_errors_without_deadlock() {
    let dir = sim_dir("fault_session");
    let reg =
        Arc::new(ArtifactRegistry::open_simulated_with_fault(&dir, 0, "stem_fwd").unwrap());
    assert!(reg.is_simulated());
    let engine = Engine::builder().registry(reg).devices(2).build().unwrap();
    assert_eq!(engine.device_count(), 2);
    let cfg = engine.config().clone();
    let mut session = engine.session(SessionConfig::with_method("anode")).unwrap();
    let micro = micro_batches(&cfg, 6);

    for round in 0..2 {
        let err = session
            .step_accumulate_with_workers(&micro, 2)
            .expect_err("a faulty device must fail the step");
        assert!(err.to_string().contains("injected fault"), "round {round}: {err}");
    }
    let eval: Vec<(Tensor, Tensor)> =
        (0..6).map(|k| (image(&cfg, k), labels(&cfg, k))).collect();
    let err = session
        .evaluate_with_workers(&eval, 2)
        .expect_err("a faulty device must fail evaluation");
    assert!(err.to_string().contains("injected fault"), "{err}");
    // Reaching drop without a hang proves the pools drained and joined.
    drop(session);
    std::fs::remove_dir_all(&dir).ok();
}

/// The engine builder honors `ANODE_SIM_DEVICES` as the default device
/// count (unless an explicit count or a shared registry pins it), and the
/// session/serve paths report the same topology.
#[test]
fn device_topology_is_visible_end_to_end() {
    let dir = sim_dir("topology");
    for devices in device_grid() {
        let engine = sim_engine(&dir, devices);
        assert_eq!(engine.device_count(), devices);
        assert_eq!(engine.device_set().count(), devices);
        for d in 0..devices {
            assert_eq!(engine.device_set().registry(d).device_id(), d);
            // `simulate(true)` resolves to an offline backend — Sim by
            // default, Compiled when `ANODE_BACKEND` retargets the suite.
            assert_ne!(engine.device_set().registry(d).backend(), Backend::Xla);
        }
        let session = engine.session(SessionConfig::default()).unwrap();
        assert_eq!(session.device_count(), devices);
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Backend axis: sim interpreter vs compiled kernel plans
// ---------------------------------------------------------------------------

/// An engine pinned to an explicit execution backend. The backend axis
/// must stay meaningful under the CI `ANODE_BACKEND` legs, so these
/// tests never rely on default resolution (explicit builder choice beats
/// the environment).
fn backend_engine(dir: &Path, devices: usize, backend: Backend) -> Engine {
    Engine::builder().artifacts(dir).devices(devices).backend(backend).build().unwrap()
}

/// The backend axis on the training grid: the compiled plans must be
/// bit-identical to the sim interpreter for every (devices × workers ×
/// strategy) combination — per-step losses, final params, and ledger
/// traffic. This is the lock-in for the compiled backend's core claim:
/// same values, fewer per-call costs.
#[test]
fn backend_axis_training_grid_compiled_bitwise_equal_to_sim() {
    let dir = sim_dir("backend_train");
    let sim_serial = backend_engine(&dir, 1, Backend::Sim);
    assert_eq!(sim_serial.device_set().registry(0).backend(), Backend::Sim);
    let compiled: Vec<(usize, Engine)> = device_grid()
        .into_iter()
        .map(|d| (d, backend_engine(&dir, d, Backend::Compiled)))
        .collect();
    for (devices, engine) in &compiled {
        for d in 0..*devices {
            let reg = engine.device_set().registry(d);
            assert_eq!(reg.backend(), Backend::Compiled);
            let stats = reg.compile_stats().expect("compiled registries expose plan stats");
            assert!(stats.plans_cached > 0, "eager compile must cache the manifest modules");
        }
    }
    for method in STRATEGIES {
        let (loss_ref, params_ref, traffic_ref) = train_run(&sim_serial, method, 1, 2);
        for (devices, engine) in &compiled {
            for workers in [1usize, 2, 4] {
                let (loss, params, traffic) = train_run(engine, method, workers, 2);
                assert_eq!(
                    loss_ref, loss,
                    "{method}: compiled losses diverged at devices={devices} workers={workers}"
                );
                assert_eq!(
                    params_ref, params,
                    "{method}: compiled params diverged at devices={devices} workers={workers}"
                );
                assert_eq!(
                    traffic_ref, traffic,
                    "{method}: compiled ledger traffic diverged at devices={devices} \
                     workers={workers}"
                );
            }
        }
    }

    // Train-arena accounting across the grid: every micro-batch above ran
    // through a fused TrainProgram, so per engine the pooled-arena pops
    // (warmup allocations + steady-state reuses) must account for every
    // run exactly — with reuse actually happening — and every device's
    // build-time counters must show the checkpoint lowering.
    let runs_per_engine = (STRATEGIES.len() * 3 * 2 * 4) as u64;
    for (devices, engine) in &compiled {
        let mut allocs = 0u64;
        let mut reuses = 0u64;
        for d in 0..*devices {
            let stats = engine.device_set().registry(d).compile_stats().unwrap();
            assert!(stats.trajectory_bytes > 0, "device {d}: no trajectory slots planned");
            assert!(stats.train_recompute_segments > 0, "device {d}: revolve never unrolled");
            allocs += stats.train_arena_allocs;
            reuses += stats.train_arena_reuses;
        }
        assert_eq!(
            allocs + reuses,
            runs_per_engine,
            "devices={devices}: arena pops must account for every micro-batch run"
        );
        assert!(allocs < runs_per_engine, "devices={devices}: arena reuse never happened");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The backend axis on the prediction and serving paths: the compiled
/// engine's fused inference program must reproduce the sim serial logits
/// and replies bitwise across the (devices × workers) grid, with ledger
/// traffic equal to serial (the compiled path changes execution, never
/// the memory model).
#[test]
fn backend_axis_predict_and_serve_compiled_match_sim_serial() {
    let dir = sim_dir("backend_predict");
    let sim_serial = backend_engine(&dir, 1, Backend::Sim);
    let cfg = sim_serial.config().clone();
    let batches: Vec<Tensor> = (0..4).map(|k| image(&cfg, 300 + k)).collect();
    let serial_session = sim_serial.session(SessionConfig::with_method("anode")).unwrap();
    let expected = serial_session.predict_batches_with_workers(&batches, 1).unwrap();

    for devices in device_grid() {
        let engine = backend_engine(&dir, devices, Backend::Compiled);
        let session = engine.session(SessionConfig::with_method("anode")).unwrap();
        for workers in [1usize, 2, 4] {
            let par = session.predict_batches_with_workers(&batches, workers).unwrap();
            assert_eq!(par.predictions.len(), expected.predictions.len());
            for (s, p) in expected.predictions.iter().zip(&par.predictions) {
                assert_eq!(s.classes, p.classes, "devices={devices} workers={workers}");
                assert_eq!(
                    s.logits.data(),
                    p.logits.data(),
                    "compiled logits diverged at devices={devices} workers={workers}"
                );
            }
            assert_eq!(
                par.memory.total_traffic(),
                expected.memory.total_traffic(),
                "devices={devices} workers={workers}"
            );
            assert_eq!(par.memory.unknown_frees(), 0);
        }

        // One serve pass per device count locks the wire path in too.
        let config = ServeConfig::default().max_delay_ms(600_000).workers(2).queue_cap(256);
        let handle = session.serve(config).unwrap();
        assert_eq!(handle.device_count(), devices);
        let mut pendings: Vec<Pending> = Vec::new();
        for batch in &batches {
            for ex in anode::serve::split_examples(batch).unwrap() {
                pendings.push(handle.submit(ex).unwrap());
            }
        }
        let mut idx = 0usize;
        for pred in &expected.predictions {
            let k = *pred.logits.shape().last().unwrap();
            for r in 0..cfg.batch {
                let reply =
                    pendings[idx].wait_timeout(WAIT).unwrap().expect("serve reply timed out");
                assert_eq!(reply.class, pred.classes[r], "request {idx} devices={devices}");
                assert_eq!(
                    reply.logits.data(),
                    &pred.logits.data()[r * k..(r + 1) * k],
                    "request {idx} devices={devices}"
                );
                idx += 1;
            }
        }
        let report = handle.shutdown().unwrap();
        assert_eq!(report.requests, (batches.len() * cfg.batch) as u64);
        assert_eq!(
            report.memory.total_traffic(),
            expected.memory.total_traffic(),
            "compiled serve ledger traffic diverged from sim serial predict \
             (devices={devices})"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}
