//! Integration tests over the full stack: artifacts → runtime → execution
//! core → api session. Requires `make artifacts`; each test skips
//! gracefully if the artifacts are missing.

use std::path::Path;
use std::sync::Arc;

use anode::api::{Engine, FitOptions, LrSchedule, SessionConfig};
use anode::coordinator::Coordinator;
use anode::data::{make_eval_batches, Batcher, SyntheticCifar};
use anode::memory::{Category, MemoryLedger};
use anode::models::{Arch, GradMethod, ModelConfig, Solver};
use anode::runtime::ArtifactRegistry;
use anode::tensor::Tensor;

fn registry() -> Option<Arc<ArtifactRegistry>> {
    let p = Path::new("artifacts");
    if !p.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Arc::new(ArtifactRegistry::open(p).unwrap()))
}

fn small_data(ncls: usize, n: usize, batch: usize) -> (Batcher, Vec<(Tensor, Tensor)>) {
    let ds = SyntheticCifar::new(ncls, 11, 0.1);
    let (imgs, labels) = ds.generate(n, 1);
    let (timgs, tlabels) = ds.generate(batch * 2, 2);
    let eval = make_eval_batches(&timgs, &tlabels, batch, 2);
    (Batcher::new(imgs, labels, batch, false, 3).unwrap(), eval)
}

#[test]
fn forward_shapes_and_memory_accounting() {
    let Some(reg) = registry() else { return };
    let cfg = ModelConfig::from_registry(&reg, Arch::Resnet, 10).unwrap();
    let batch = cfg.batch;
    let co = Coordinator::new(reg.clone(), cfg, Solver::Euler, GradMethod::Anode).unwrap();
    let params = co.load_params().unwrap();

    let ds = SyntheticCifar::new(10, 5, 0.1);
    let (imgs, _) = ds.generate(batch, 0);
    let mut ledger = MemoryLedger::new();
    let state = co.forward(&imgs, &params, &mut ledger).unwrap();

    assert_eq!(state.block_inputs.len(), 3);
    assert_eq!(state.block_inputs[0].len(), 2);
    assert_eq!(state.block_inputs[0][0].shape(), &[batch, 32, 32, 16]);
    assert_eq!(state.block_inputs[2][0].shape(), &[batch, 8, 8, 64]);
    assert_eq!(state.z_final.shape(), &[batch, 8, 8, 64]);
    assert!(state.z_final.all_finite());
    // O(L) accounting: x + 6 block inputs + 2 transition inputs tracked.
    assert!(ledger.peak_of(Category::BlockInput) > 0);
    assert_eq!(ledger.peak_of(Category::StepState), 0);
}

#[test]
fn grads_flow_and_are_finite_for_all_methods() {
    let Some(reg) = registry() else { return };
    let cfg = ModelConfig::from_registry(&reg, Arch::Resnet, 10).unwrap();
    let batch = cfg.batch;
    let ds = SyntheticCifar::new(10, 6, 0.1);
    let (imgs, labels) = ds.generate(batch, 0);
    let y = Tensor::from_vec(vec![batch], labels.iter().map(|&l| l as f32).collect()).unwrap();

    for method in [
        GradMethod::Anode,
        GradMethod::Otd,
        GradMethod::Node,
        GradMethod::AnodeRevolve(2),
        GradMethod::AnodeEquispaced(2),
    ] {
        let co = Coordinator::new(reg.clone(), cfg.clone(), Solver::Euler, method).unwrap();
        let params = co.load_params().unwrap();
        let mut ledger = MemoryLedger::new();
        let (loss, correct, grads) =
            co.loss_and_grad(&imgs, &y, &params, &mut ledger).unwrap();
        assert!(loss.is_finite() && loss > 0.0, "{method:?}: loss {loss}");
        assert!((0.0..=batch as f32).contains(&correct));
        assert_eq!(grads.len(), params.len());
        let gnorm: f32 = grads.iter().map(|g| g.norm2()).sum();
        assert!(gnorm.is_finite() && gnorm > 0.0, "{method:?}: grad norm {gnorm}");
        // All stored activations released after the step.
        assert_eq!(ledger.current_of(Category::BlockInput), 0, "{method:?}");
        assert_eq!(ledger.current_of(Category::StepState), 0, "{method:?}");
    }
}

#[test]
fn anode_and_revolve_gradients_agree_exactly() {
    // Revolve recomputes the same discrete states, so its gradient must
    // match the fused DTO VJP to float tolerance — THE correctness claim
    // for the checkpointed coordinator.
    let Some(reg) = registry() else { return };
    let cfg = ModelConfig::from_registry(&reg, Arch::Resnet, 10).unwrap();
    let batch = cfg.batch;
    let ds = SyntheticCifar::new(10, 7, 0.1);
    let (imgs, labels) = ds.generate(batch, 0);
    let y = Tensor::from_vec(vec![batch], labels.iter().map(|&l| l as f32).collect()).unwrap();

    let run = |method| {
        let co = Coordinator::new(reg.clone(), cfg.clone(), Solver::Euler, method).unwrap();
        let params = co.load_params().unwrap();
        let mut ledger = MemoryLedger::new();
        co.loss_and_grad(&imgs, &y, &params, &mut ledger).unwrap()
    };
    let (l_a, _, g_a) = run(GradMethod::Anode);
    let (l_r, _, g_r) = run(GradMethod::AnodeRevolve(2));
    let (l_e, _, g_e) = run(GradMethod::AnodeEquispaced(3));
    assert!((l_a - l_r).abs() < 1e-5);
    assert!((l_a - l_e).abs() < 1e-5);
    for ((a, r), e) in g_a.iter().zip(&g_r).zip(&g_e) {
        let da = a.rel_err(r).unwrap();
        let de = a.rel_err(e).unwrap();
        assert!(da < 2e-4, "revolve grad mismatch {da}");
        assert!(de < 2e-4, "equispaced grad mismatch {de}");
    }
}

#[test]
fn node_gradient_differs_from_anode() {
    // §III: the [8] gradient is corrupted for generic blocks — it must NOT
    // agree with DTO (if it did, the paper would have no point).
    let Some(reg) = registry() else { return };
    let cfg = ModelConfig::from_registry(&reg, Arch::Resnet, 10).unwrap();
    let batch = cfg.batch;
    let ds = SyntheticCifar::new(10, 8, 0.1);
    let (imgs, labels) = ds.generate(batch, 0);
    let y = Tensor::from_vec(vec![batch], labels.iter().map(|&l| l as f32).collect()).unwrap();

    let run = |method| {
        let co = Coordinator::new(reg.clone(), cfg.clone(), Solver::Euler, method).unwrap();
        let params = co.load_params().unwrap();
        let mut ledger = MemoryLedger::new();
        co.loss_and_grad(&imgs, &y, &params, &mut ledger).unwrap()
    };
    let (_, _, g_a) = run(GradMethod::Anode);
    let (_, _, g_n) = run(GradMethod::Node);
    let total_rel: f32 = g_a
        .iter()
        .zip(&g_n)
        .map(|(a, n)| a.rel_err(n).unwrap_or(0.0))
        .sum::<f32>()
        / g_a.len() as f32;
    assert!(total_rel > 1e-3, "node gradient suspiciously equal to DTO: {total_rel}");
}

#[test]
fn short_training_decreases_loss() {
    let Some(reg) = registry() else { return };
    let engine = Engine::builder().registry(reg.clone()).build().unwrap();
    let batch = engine.config().batch;
    let session_cfg = SessionConfig {
        method: "anode".into(),
        lr: LrSchedule::Constant(0.05),
        ..Default::default()
    };
    let mut session = engine.session(session_cfg).unwrap();
    let (mut train, eval) = small_data(10, batch * 8, batch);
    let opts = FitOptions { steps: 16, eval_every: 8, verbose: false, ..Default::default() };
    let res = session.fit(&mut train, &eval, &opts, "itest").unwrap();
    assert!(!res.diverged);
    assert_eq!(res.steps_run, 16);
    assert_eq!(session.steps_taken(), 16);
    let first = res.curve.points.first().unwrap().train_loss;
    let last = res.curve.points.last().unwrap().train_loss;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    assert!(res.peak_activation_bytes > 0);
}

#[test]
fn sqnxt_arch_works_with_rk2() {
    let Some(reg) = registry() else { return };
    let cfg = ModelConfig::from_registry(&reg, Arch::Sqnxt, 10).unwrap();
    let batch = cfg.batch;
    let co = Coordinator::new(reg.clone(), cfg, Solver::Rk2, GradMethod::Anode).unwrap();
    let params = co.load_params().unwrap();
    let ds = SyntheticCifar::new(10, 9, 0.1);
    let (imgs, labels) = ds.generate(batch, 0);
    let y = Tensor::from_vec(vec![batch], labels.iter().map(|&l| l as f32).collect()).unwrap();
    let mut ledger = MemoryLedger::new();
    let (loss, _, grads) = co.loss_and_grad(&imgs, &y, &params, &mut ledger).unwrap();
    assert!(loss.is_finite());
    assert!(grads.iter().all(|g| g.all_finite()));
}

#[test]
fn cifar100_head_works() {
    let Some(reg) = registry() else { return };
    let cfg = ModelConfig::from_registry(&reg, Arch::Resnet, 100).unwrap();
    let batch = cfg.batch;
    let co = Coordinator::new(reg.clone(), cfg, Solver::Euler, GradMethod::Anode).unwrap();
    let params = co.load_params().unwrap();
    let ds = SyntheticCifar::new(100, 10, 0.1);
    let (imgs, labels) = ds.generate(batch, 0);
    let y = Tensor::from_vec(vec![batch], labels.iter().map(|&l| l as f32).collect()).unwrap();
    let mut ledger = MemoryLedger::new();
    let (loss, _, _) = co.loss_and_grad(&imgs, &y, &params, &mut ledger).unwrap();
    // ln(100) ≈ 4.6 at init.
    assert!((loss - 4.6).abs() < 0.8, "cifar100 init loss {loss}");
}

#[test]
fn gradcheck_harness_reproduces_sec4_shape() {
    let Some(reg) = registry() else { return };
    let rows = anode::harness::gradient_consistency(&reg, 5).unwrap();
    assert!(rows.len() >= 4);
    // OTD error decreases as dt shrinks...
    let first = rows.first().unwrap();
    let last = rows.last().unwrap();
    assert!(last.otd_rel_err < first.otd_rel_err * 0.5);
    // ...DTO matches finite differences throughout...
    for r in &rows {
        assert!(r.dto_fd_err < 0.05, "nt={}: fd err {}", r.nt, r.dto_fd_err);
    }
    // ...and the [8] reconstruction error stays O(1)-large at coarse dt.
    assert!(first.node_recon_err > 0.5);
}
