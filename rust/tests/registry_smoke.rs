//! Integration: manifest-driven registry loads, compiles and runs real
//! AOT artifacts (requires `make artifacts` to have run).

use std::path::Path;

use anode::runtime::ArtifactRegistry;
use anode::tensor::Tensor;

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts/ not built");
        None
    }
}

#[test]
fn loads_manifest_and_runs_tiny_block() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = ArtifactRegistry::open(dir).unwrap();
    assert!(reg.module_names().len() > 50);
    assert!(reg.has_module("tiny_euler_nt4_fwd"));

    let spec = reg.module_spec("tiny_euler_nt4_fwd").unwrap().clone();
    let inputs: Vec<Tensor> =
        spec.inputs.iter().map(|s| Tensor::full(&s.shape, 0.1)).collect();
    let refs: Vec<&Tensor> = inputs.iter().collect();
    let out = reg.call("tiny_euler_nt4_fwd", &refs).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape(), spec.outputs[0].shape.as_slice());
    assert!(out[0].all_finite());
    assert_eq!(reg.compiled_count(), 1);
}

#[test]
fn vjp_matches_finite_difference_on_tiny_block() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = ArtifactRegistry::open(dir).unwrap();
    let name_fwd = "tiny_euler_nt4_fwd";
    let name_vjp = "tiny_euler_nt4_vjp";
    let spec = reg.module_spec(name_fwd).unwrap().clone();

    // Small deterministic inputs.
    let mut rng = anode::rng::Rng::new(9);
    let inputs: Vec<Tensor> = spec
        .inputs
        .iter()
        .map(|s| {
            let n: usize = s.shape.iter().product();
            Tensor::from_vec(s.shape.clone(), rng.normal_vec(n).iter().map(|x| 0.2 * x).collect())
                .unwrap()
        })
        .collect();
    let g = Tensor::full(&spec.outputs[0].shape, 1.0); // dL/dz1 = 1 => L = sum(z1)

    let mut vjp_in: Vec<&Tensor> = inputs.iter().collect();
    vjp_in.push(&g);
    let grads = reg.call(name_vjp, &vjp_in).unwrap();
    let gz = &grads[0];

    // Finite-difference check on a few coordinates of z.
    let sum = |t: &Tensor| t.data().iter().map(|&x| x as f64).sum::<f64>();
    let eps = 1e-3f32;
    for &idx in &[0usize, 17, 101] {
        let mut plus = inputs.clone();
        plus[0].data_mut()[idx] += eps;
        let mut minus = inputs.clone();
        minus[0].data_mut()[idx] -= eps;
        let fp = sum(&reg.call(name_fwd, &plus.iter().collect::<Vec<_>>()).unwrap()[0]);
        let fm = sum(&reg.call(name_fwd, &minus.iter().collect::<Vec<_>>()).unwrap()[0]);
        let fd = ((fp - fm) / (2.0 * eps as f64)) as f32;
        let ad = gz.data()[idx];
        assert!(
            (fd - ad).abs() < 1e-2 * (1.0 + ad.abs()),
            "fd {fd} vs ad {ad} at {idx}"
        );
    }
}

#[test]
fn params_bin_loads_for_all_models() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = ArtifactRegistry::open(dir).unwrap();
    for model in ["resnet10", "resnet100", "sqnxt10", "sqnxt100"] {
        let params = reg.load_params(model).unwrap();
        assert!(params.len() > 20, "{model}: {}", params.len());
        assert!(params.iter().all(|p| p.all_finite()));
        // He-init weights are non-degenerate.
        let total_norm: f32 = params.iter().map(|p| p.norm2()).sum();
        assert!(total_norm > 1.0);
    }
}
