//! Concurrency tests for the thread-safe execution core.
//!
//! Stub-safe tests (synthetic manifest, no compiled artifacts) prove the
//! shared layers are `Send + Sync` and survive concurrent use; the
//! artifact-gated tests prove the strong property: parallel execution —
//! evaluation, prediction *and* data-parallel gradient accumulation
//! across every registered strategy — is **bit-identical** to serial,
//! and per-worker ledger merges account for exactly the serial traffic.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anode::api::{make_eval_batches, Engine, SessionConfig};
use anode::coordinator::ExecutionCore;
use anode::data::SyntheticCifar;
use anode::memory::MemoryLedger;
use anode::runtime::ArtifactRegistry;
use anode::tensor::Tensor;

// ---------------------------------------------------------------------------
// Compile-time + stub-safe checks (run anywhere)
// ---------------------------------------------------------------------------

#[test]
fn execution_stack_is_send_and_sync() {
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<ArtifactRegistry>();
    assert_send_sync::<ExecutionCore>();
    assert_send_sync::<Engine>();
    assert_send_sync::<MemoryLedger>();
}

/// Write a synthetic manifest + params.bin good enough to build an engine
/// and create sessions (module *execution* still needs a real backend).
fn fake_artifacts_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("anode_conc_test_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let mut modules = String::new();
    let mut add = |name: &str| {
        if !modules.is_empty() {
            modules.push(',');
        }
        modules.push_str(&format!(
            r#"{{"name":"{name}","file":"{name}.hlo.txt","inputs":[],"outputs":[]}}"#
        ));
    };
    for name in [
        "stem_fwd",
        "stem_vjp",
        "trans0_fwd",
        "trans0_vjp",
        "trans1_fwd",
        "trans1_vjp",
        "head10_loss_grad",
        "head10_eval",
    ] {
        add(name);
    }
    for s in 0..3 {
        for kind in ["fwd", "vjp", "node"] {
            add(&format!("block_resnet_s{s}_euler_{kind}"));
        }
    }

    let mut params = String::new();
    let mut push = |name: &str| {
        if !params.is_empty() {
            params.push(',');
        }
        params.push_str(&format!(r#"{{"name":"{name}","shape":[1],"offset":0}}"#));
    };
    push("stem.w");
    push("stem.b");
    for s in 0..3 {
        for b in 0..2 {
            for leaf in ["w1", "b1", "w2", "b2"] {
                push(&format!("s{s}.b{b}.{leaf}"));
            }
        }
        if s < 2 {
            push(&format!("trans{s}.w"));
            push(&format!("trans{s}.b"));
        }
    }
    push("head.w");
    push("head.b");

    let manifest = format!(
        r#"{{
  "modules": [{modules}],
  "params": {{"resnet10": [{params}]}},
  "config": {{"batch": 32, "image": 32, "blocks_per_stage": 2, "nt": 4,
              "channels": [16, 32, 64]}}
}}"#
    );
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    // One f32 — every synthetic param is shape [1] at offset 0.
    std::fs::write(dir.join("params.bin"), 0f32.to_le_bytes()).unwrap();
    dir
}

#[test]
fn one_engine_serves_sessions_on_many_threads() {
    let dir = fake_artifacts_dir("sessions");
    let engine = Engine::builder().artifacts(&dir).build().unwrap();

    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..4usize {
            let engine = &engine;
            handles.push(scope.spawn(move || {
                let method = if t % 2 == 0 { "anode" } else { "node" };
                let session = engine.session(SessionConfig::with_method(method)).unwrap();
                assert_eq!(session.method_name(), method);
                assert_eq!(session.steps_taken(), 0);
                // Params + optimizer state are on the session's own ledger.
                assert!(session.memory().peak_bytes() > 0);
                // Registry-level reads race freely.
                assert!(engine.registry().has_module("stem_fwd"));
                method.len()
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn concurrent_compile_misses_fail_cleanly_on_stub() {
    // The synthetic manifest has no .hlo.txt files (and the offline stub
    // could not compile them anyway): racing executable lookups must all
    // surface typed errors without poisoning the shared cache.
    let dir = fake_artifacts_dir("compile_race");
    let reg = Arc::new(ArtifactRegistry::open(&dir).unwrap());

    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..4 {
            let reg = reg.clone();
            handles.push(scope.spawn(move || {
                for _ in 0..8 {
                    let err = reg.get("stem_fwd").err().expect("stub compile must fail");
                    let msg = err.to_string();
                    assert!(
                        msg.contains("stem_fwd") || msg.contains("stub"),
                        "unexpected error: {msg}"
                    );
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });
    // The cache stayed usable (and empty) after the failed races.
    assert_eq!(reg.compiled_count(), 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn pooled_paths_error_cleanly_and_stay_reusable_on_stub() {
    // The synthetic manifest builds an engine but module *execution*
    // fails on the stub: the pooled fan-outs must surface that error —
    // no hang, no panic — and the session's cached pool must stay
    // reusable for later calls.
    let dir = fake_artifacts_dir("pooled_paths");
    let engine = Engine::builder().artifacts(&dir).build().unwrap();
    let mut session = engine.session(SessionConfig::with_method("anode")).unwrap();
    let cfg = engine.config().clone();
    let imgs = Tensor::zeros(&[cfg.batch, cfg.image, cfg.image, 3]);
    let y = Tensor::zeros(&[cfg.batch]);

    let eval: Vec<(Tensor, Tensor)> = (0..4).map(|_| (imgs.clone(), y.clone())).collect();
    for round in 0..2 {
        assert!(session.evaluate_with_workers(&eval, 4).is_err(), "round {round}");
    }
    let micro: Vec<(Tensor, Tensor)> = (0..4).map(|_| (imgs.clone(), y.clone())).collect();
    assert!(session.step_accumulate_with_workers(&micro, 4).is_err());

    // Validation failures fire before any execution or pool use.
    assert!(session.step_accumulate(&[]).is_err(), "empty micro-batch list must be rejected");
    let bad = vec![(Tensor::zeros(&[1, 2, 2, 3]), y.clone())];
    assert!(session.step_accumulate(&bad).is_err(), "wrong batch shape must be rejected");
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Artifact-gated: bit-identical parallel execution
// ---------------------------------------------------------------------------

fn real_engine() -> Option<Engine> {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Engine::builder().artifacts("artifacts").build().unwrap())
}

/// Train `steps` optimizer steps from a fresh session and return every
/// loss as raw bits (bitwise comparison — no tolerance).
fn train_losses(engine: &Engine, seed: u64, steps: usize) -> Vec<u32> {
    let mut session = engine.session(SessionConfig::with_method("anode")).unwrap();
    let cfg = engine.config().clone();
    let ds = SyntheticCifar::new(cfg.num_classes, seed, 0.1);
    let mut losses = Vec::with_capacity(steps);
    for k in 0..steps {
        let (imgs, labels) = ds.generate(cfg.batch, k as u64);
        let y =
            Tensor::from_vec(vec![cfg.batch], labels.iter().map(|&l| l as f32).collect()).unwrap();
        let stats = session.step(&imgs, &y).unwrap();
        losses.push(stats.loss.to_bits());
    }
    losses
}

#[test]
fn two_threaded_sessions_match_serial_training_bitwise() {
    let Some(engine) = real_engine() else { return };
    let steps = 4;

    // Serial reference: two independent sessions, one after the other.
    let serial_a = train_losses(&engine, 101, steps);
    let serial_b = train_losses(&engine, 202, steps);
    assert_ne!(serial_a, serial_b, "distinct seeds must differ");

    // Same two sessions, concurrently, over the same shared engine (and
    // compiled-module cache).
    let (thread_a, thread_b) = std::thread::scope(|scope| {
        let ha = scope.spawn(|| train_losses(&engine, 101, steps));
        let hb = scope.spawn(|| train_losses(&engine, 202, steps));
        (ha.join().unwrap(), hb.join().unwrap())
    });

    assert_eq!(serial_a, thread_a, "session A diverged under concurrency");
    assert_eq!(serial_b, thread_b, "session B diverged under concurrency");
}

/// Train `steps` accumulate-steps (`accum` micro-batches each) from a
/// fresh session with the given gradient strategy and worker count.
/// Returns (per-step loss bits, final param bits, training ledger
/// traffic) for bitwise comparison against other worker counts.
fn train_accumulate(
    engine: &Engine,
    method: &str,
    workers: usize,
    accum: usize,
    steps: usize,
) -> (Vec<u32>, Vec<u32>, u64) {
    let mut session = engine.session(SessionConfig::with_method(method)).unwrap();
    let cfg = engine.config().clone();
    let ds = SyntheticCifar::new(cfg.num_classes, 77, 0.1);
    let traffic0 = session.memory().total_traffic();
    let mut losses = Vec::with_capacity(steps);
    for s in 0..steps {
        let micro: Vec<(Tensor, Tensor)> = (0..accum)
            .map(|m| {
                let (imgs, labels) = ds.generate(cfg.batch, (s * accum + m) as u64);
                let lf: Vec<f32> = labels.iter().map(|&l| l as f32).collect();
                (imgs, Tensor::from_vec(vec![cfg.batch], lf).unwrap())
            })
            .collect();
        let stats = session.step_accumulate_with_workers(&micro, workers).unwrap();
        assert!(stats.finite, "{method} diverged at step {s} (workers={workers})");
        losses.push(stats.loss.to_bits());
    }
    let mut params = Vec::new();
    for p in session.params() {
        params.extend(p.data().iter().map(|x| x.to_bits()));
    }
    assert_eq!(session.memory().unknown_frees(), 0, "{method} workers={workers}");
    let traffic = session.memory().total_traffic() - traffic0;
    (losses, params, traffic)
}

/// The PR 4 acceptance grid: workers ∈ {1, 2, 4, 8} × every registered
/// gradient strategy, asserting parameters and losses bitwise-equal to
/// the serial run after k accumulate-steps, plus ledger-merge traffic
/// equality on the training path.
///
/// This grid is also the regression lock for the pipelined
/// reduce/apply in `step_accumulate_with_workers`: gradients are now
/// folded into the accumulator as shards complete (streaming, not
/// barrier-then-reduce), and the fold order is fixed by micro-batch
/// index — so every cell here must stay bitwise-equal to workers=1.
#[test]
fn data_parallel_grad_accumulation_is_bit_identical_for_all_strategies() {
    let Some(engine) = real_engine() else { return };
    let (accum, steps) = (4usize, 2usize);
    for method in [
        "anode",
        "node",
        "otd",
        "anode-revolve3",
        "anode-equispaced2",
        "symplectic",
        "interp-adjoint3",
    ] {
        let (loss1, params1, traffic1) = train_accumulate(&engine, method, 1, accum, steps);
        for workers in [2usize, 4, 8] {
            let (loss_w, params_w, traffic_w) =
                train_accumulate(&engine, method, workers, accum, steps);
            assert_eq!(loss1, loss_w, "{method}: losses diverged at workers={workers}");
            assert_eq!(params1, params_w, "{method}: params diverged at workers={workers}");
            assert_eq!(
                traffic1, traffic_w,
                "{method}: training ledger traffic diverged at workers={workers}"
            );
        }
    }
}

#[test]
fn parallel_evaluate_is_bit_identical_to_serial() {
    let Some(engine) = real_engine() else { return };
    let session = engine.session(SessionConfig::with_method("anode")).unwrap();
    let cfg = engine.config().clone();
    let ds = SyntheticCifar::new(cfg.num_classes, 33, 0.1);
    let (imgs, labels) = ds.generate(cfg.batch * 6, 0);
    let eval = make_eval_batches(&imgs, &labels, cfg.batch, 6);

    let serial = session.evaluate_with_workers(&eval, 1).unwrap();
    for workers in [2, 3, 4, 8] {
        let par = session.evaluate_with_workers(&eval, workers).unwrap();
        assert_eq!(serial.loss.to_bits(), par.loss.to_bits(), "workers={workers}");
        assert_eq!(serial.accuracy.to_bits(), par.accuracy.to_bits(), "workers={workers}");
        assert_eq!(par.batches, 6);
    }
}

#[test]
fn parallel_predict_matches_serial_and_merges_ledgers() {
    let Some(engine) = real_engine() else { return };
    let session = engine.session(SessionConfig::with_method("anode")).unwrap();
    let cfg = engine.config().clone();
    let ds = SyntheticCifar::new(cfg.num_classes, 44, 0.1);
    let batches: Vec<Tensor> = (0..8).map(|k| ds.generate(cfg.batch, k as u64).0).collect();

    let serial = session.predict_batches_with_workers(&batches, 1).unwrap();
    let par = session.predict_batches_with_workers(&batches, 4).unwrap();

    assert_eq!(serial.predictions.len(), 8);
    assert_eq!(par.predictions.len(), 8);
    for (s, p) in serial.predictions.iter().zip(&par.predictions) {
        assert_eq!(s.classes, p.classes);
        assert_eq!(s.logits.data(), p.logits.data(), "logits must be bit-identical");
    }
    // Ledger-merge accounting: the aggregate of the 4 worker ledgers sees
    // exactly the traffic of the serial sweep, with no double/unknown
    // frees on any worker.
    assert_eq!(par.memory.total_traffic(), serial.memory.total_traffic());
    assert_eq!(par.memory.unknown_frees(), 0);
    assert!(par.workers > 1);
    // Concurrent workers may hold more peak bytes in aggregate, never less.
    assert!(par.memory.peak_bytes() >= serial.memory.peak_bytes());
}
