use anode::runtime::ArtifactRegistry;
use anode::tensor::Tensor;
use std::time::Instant;

fn main() {
    let reg = ArtifactRegistry::open(std::path::Path::new("artifacts")).unwrap();
    for name in ["stem_fwd", "block_resnet_s0_euler_fwd", "block_resnet_s0_euler_vjp",
                 "block_resnet_s1_euler_fwd", "block_resnet_s1_euler_vjp",
                 "block_resnet_s2_euler_fwd", "block_resnet_s2_euler_vjp",
                 "block_sqnxt_s0_euler_fwd", "block_sqnxt_s0_euler_vjp",
                 "trans0_fwd", "head10_loss_grad"] {
        let spec = reg.module_spec(name).unwrap().clone();
        let inputs: Vec<Tensor> = spec.inputs.iter().map(|s| Tensor::full(&s.shape, 0.1)).collect();
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let t0 = Instant::now();
        reg.call(name, &refs).unwrap();
        let compile_and_first = t0.elapsed();
        let t1 = Instant::now();
        for _ in 0..3 { reg.call(name, &refs).unwrap(); }
        println!("{:<32} first(incl compile)={:>8.1?} warm={:>8.1?}", name, compile_and_first, t1.elapsed()/3);
    }
}
