//! §V memory/recompute trade-off demo: run the SAME gradient computation
//! under decreasing memory budgets — fused ANODE (O(Nt) inside the block),
//! revolve(m) for shrinking m, and the O(1) extreme — and verify the
//! gradients agree bit-for-bit while memory drops and recompute rises.
//!
//! Each budget is one `anode::api` Session; all sessions share one Engine
//! (and its compiled-module cache) and load identical initial parameters.
//!
//!     make artifacts && cargo run --release --example memory_budget

use anode::api::{Engine, SessionConfig};
use anode::checkpoint::{min_recomputations, plan, Strategy};
use anode::data::SyntheticCifar;
use anode::memory::{human_bytes, Category};
use anode::tensor::Tensor;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let engine = Engine::builder().artifacts("artifacts").build()?;
    let cfg = engine.config().clone();
    let nt = cfg.nt;
    let batch = cfg.batch;

    let ds = SyntheticCifar::new(10, 21, 0.1);
    let (imgs, labels) = ds.generate(batch, 0);
    let y = Tensor::from_vec(vec![batch], labels.iter().map(|&l| l as f32).collect())?;

    println!("same batch, same parameters, shrinking memory budget (Nt = {nt}):\n");
    println!(
        "{:<22} {:>16} {:>16} {:>14} {:>12}",
        "method", "peak block-input", "peak step-state", "fwd evals/blk", "‖grads‖"
    );

    let mut reference: Option<Vec<Tensor>> = None;
    let methods: [(&str, u64); 5] = [
        ("anode", nt as u64),
        ("anode-revolve3", min_recomputations(nt, 3)),
        ("anode-revolve2", min_recomputations(nt, 2)),
        ("anode-revolve1", min_recomputations(nt, 1)),
        ("anode-equispaced2", plan(Strategy::Equispaced(2), nt).forward_evals() as u64),
    ];
    for (method, evals) in methods {
        let mut session = engine.session(SessionConfig::with_method(method))?;
        let (_, _, grads) = session.loss_and_grad(&imgs, &y)?;
        let gnorm: f32 = grads.iter().map(|g| g.norm2()).sum();
        println!(
            "{:<22} {:>16} {:>16} {:>14} {:>12.5}",
            method,
            human_bytes(session.memory().peak_of(Category::BlockInput)),
            human_bytes(session.memory().peak_of(Category::StepState)),
            evals,
            gnorm
        );
        match &reference {
            None => reference = Some(grads),
            Some(r) => {
                let max_rel = r
                    .iter()
                    .zip(&grads)
                    .map(|(a, b)| a.rel_err(b).unwrap_or(f32::INFINITY))
                    .fold(0.0f32, f32::max);
                assert!(
                    max_rel < 2e-4,
                    "{method}: gradient deviates from ANODE by {max_rel}"
                );
            }
        }
    }
    println!("\nall gradients identical (≤2e-4 rel) — memory traded for recompute only.");
    Ok(())
}
