//! §V memory/recompute trade-off demo: run the SAME gradient computation
//! under decreasing memory budgets — fused ANODE (O(Nt) inside the block),
//! revolve(m) for shrinking m, and the O(1) extreme — and verify the
//! gradients agree bit-for-bit while memory drops and recompute rises.
//!
//!     make artifacts && cargo run --release --example memory_budget

use anode::checkpoint::{min_recomputations, plan, Strategy};
use anode::coordinator::Coordinator;
use anode::data::SyntheticCifar;
use anode::memory::{human_bytes, MemoryLedger};
use anode::models::{Arch, GradMethod, ModelConfig, Solver};
use anode::runtime::ArtifactRegistry;
use anode::tensor::Tensor;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let reg = ArtifactRegistry::open(std::path::Path::new("artifacts"))?;
    let cfg = ModelConfig::from_registry(&reg, Arch::Resnet, 10)?;
    let nt = cfg.nt;
    let batch = cfg.batch;

    let ds = SyntheticCifar::new(10, 21, 0.1);
    let (imgs, labels) = ds.generate(batch, 0);
    let y = Tensor::from_vec(vec![batch], labels.iter().map(|&l| l as f32).collect())?;

    println!("same batch, same parameters, shrinking memory budget (Nt = {nt}):\n");
    println!(
        "{:<22} {:>16} {:>16} {:>14} {:>12}",
        "method", "peak block-input", "peak step-state", "fwd evals/blk", "‖grads‖"
    );

    let mut reference: Option<Vec<Tensor>> = None;
    let methods = [
        (GradMethod::Anode, nt as u64),
        (GradMethod::AnodeRevolve(3), min_recomputations(nt, 3)),
        (GradMethod::AnodeRevolve(2), min_recomputations(nt, 2)),
        (GradMethod::AnodeRevolve(1), min_recomputations(nt, 1)),
        (GradMethod::AnodeEquispaced(2), plan(Strategy::Equispaced(2), nt).forward_evals() as u64),
    ];
    for (method, evals) in methods {
        let co = Coordinator::new(&reg, cfg.clone(), Solver::Euler, method)?;
        let params = co.load_params()?;
        let mut ledger = MemoryLedger::new();
        let (_, _, grads) = co.loss_and_grad(&imgs, &y, &params, &mut ledger)?;
        let gnorm: f32 = grads.iter().map(|g| g.norm2()).sum();
        println!(
            "{:<22} {:>16} {:>16} {:>14} {:>12.5}",
            method.name(),
            human_bytes(ledger.peak_of(anode::memory::Category::BlockInput)),
            human_bytes(ledger.peak_of(anode::memory::Category::StepState)),
            evals,
            gnorm
        );
        match &reference {
            None => reference = Some(grads),
            Some(r) => {
                let max_rel = r
                    .iter()
                    .zip(&grads)
                    .map(|(a, b)| a.rel_err(b).unwrap_or(f32::INFINITY))
                    .fold(0.0f32, f32::max);
                assert!(
                    max_rel < 2e-4,
                    "{}: gradient deviates from ANODE by {max_rel}",
                    method.name()
                );
            }
        }
    }
    println!("\nall gradients identical (≤2e-4 rel) — memory traded for recompute only.");
    Ok(())
}
