//! Quickstart for the `anode::api` façade: build an Engine over the AOT
//! artifacts, open a Session, then train → evaluate → predict — the whole
//! lifecycle in one session, no raw registry or coordinator in sight.
//!
//!     make artifacts && cargo run --release --example quickstart

use anode::api::{make_eval_batches, Engine, SessionConfig};
use anode::data::{Batcher, SyntheticCifar};
use anode::util::cli::Args;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::from_env();
    let artifacts = args.get_or("artifacts", "artifacts");
    let steps: usize = args.get_parse_or("steps", 8);
    args.warn_unknown();

    // 1. Engine: opens the registry once, validates the manifest eagerly,
    //    and resolves every module into typed handles.
    let engine = Engine::builder().artifacts(&artifacts).build()?;
    let cfg = engine.config().clone();
    println!(
        "engine: arch={} classes={} batch={} nt={} ({} typed module handles)",
        cfg.arch.name(),
        cfg.num_classes,
        cfg.batch,
        cfg.nt,
        engine.modules().handle_count()
    );

    // 2. Session: owns parameters + optimizer; the gradient method is a
    //    strategy object resolved by name from the engine's registry.
    let mut session = engine.session(SessionConfig::with_method("anode"))?;
    println!("session: method={} (registered: {})", session.method_name(),
             engine.strategies().names().join(", "));

    // 3. Train a few steps on synthetic CIFAR.
    let ds = SyntheticCifar::new(cfg.num_classes, 7, 0.12);
    let (train_imgs, train_labels) = ds.generate(cfg.batch * 4, 1);
    let (test_imgs, test_labels) = ds.generate(cfg.batch * 2, 2);
    let mut train = Batcher::new(train_imgs, train_labels, cfg.batch, true, 3)?;
    let eval = make_eval_batches(&test_imgs, &test_labels, cfg.batch, 2);

    for _ in 0..steps {
        let batch = train.next_batch();
        let s = session.step(&batch.images, &batch.labels)?;
        println!(
            "step {:>3}: loss {:.4} acc {:>5.1}% |g| {:.3} ({:.0} ms)",
            s.step,
            s.loss,
            s.batch_accuracy * 100.0,
            s.grad_norm,
            s.seconds * 1e3
        );
    }

    // 4. Evaluate over the held-out batches (inference path — no gradient
    //    bookkeeping).
    let e = session.evaluate(&eval)?;
    println!(
        "eval: loss {:.4} acc {:>5.1}% over {} batches ({:.0} ms)",
        e.loss,
        e.accuracy * 100.0,
        e.batches,
        e.seconds * 1e3
    );

    // 5. Predict: the batched serving path, with per-call stats.
    let (x, y) = &eval[0];
    let p = session.predict(x)?;
    let truth: Vec<usize> = y.data().iter().map(|&v| v as usize).collect();
    let agree = p.classes.iter().zip(&truth).filter(|(a, b)| a == b).count();
    println!(
        "predict: batch={} latency {:.1} ms ({:.0} ex/s, peak act {}B) — {}/{} match labels",
        p.stats.batch,
        p.stats.seconds * 1e3,
        p.stats.examples_per_sec,
        p.stats.peak_activation_bytes,
        agree,
        truth.len()
    );
    println!("logits shape {:?}; first row: {:?}", p.logits.shape(),
             &p.logits.data()[..cfg.num_classes.min(10)]);
    println!("quickstart OK");
    Ok(())
}
