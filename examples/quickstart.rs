//! Quickstart: load the AOT artifacts, run one ODE block forward, compute
//! its ANODE (DTO) gradient, and cross-check against finite differences.
//!
//!     make artifacts && cargo run --release --example quickstart

use anode::rng::Rng;
use anode::runtime::ArtifactRegistry;
use anode::tensor::Tensor;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let reg = ArtifactRegistry::open(std::path::Path::new("artifacts"))?;
    println!("manifest: {} modules", reg.module_names().len());

    // 1. Run the tiny ODE block forward: z(1) = z(0) + ∫ f(z, θ) dt.
    let fwd = "tiny_euler_nt4_fwd";
    let spec = reg.module_spec(fwd)?.clone();
    let mut rng = Rng::new(7);
    let inputs: Vec<Tensor> = spec
        .inputs
        .iter()
        .map(|s| {
            let n: usize = s.shape.iter().product();
            Tensor::from_vec(s.shape.clone(), rng.normal_vec(n).iter().map(|x| x * 0.2).collect())
                .unwrap()
        })
        .collect();
    let refs: Vec<&Tensor> = inputs.iter().collect();
    let z1 = reg.call(fwd, &refs)?.remove(0);
    println!(
        "forward:  z0 {:?} -> z1 {:?}  (norm {:.4})",
        inputs[0].shape(),
        z1.shape(),
        z1.norm2()
    );

    // 2. ANODE gradient: reverse-mode through the discrete solver (DTO).
    let g = Tensor::full(z1.shape(), 1.0); // dL/dz1 for L = sum(z1)
    let mut vjp_in = refs.clone();
    vjp_in.push(&g);
    let grads = reg.call("tiny_euler_nt4_vjp", &vjp_in)?;
    println!(
        "backward: dL/dz0 norm {:.4}, {} parameter grads",
        grads[0].norm2(),
        grads.len() - 1
    );

    // 3. Finite-difference check on one coordinate.
    let idx = 42;
    let eps = 1e-3f32;
    let sum = |t: &Tensor| t.data().iter().map(|&x| x as f64).sum::<f64>();
    let mut plus = inputs.clone();
    plus[0].data_mut()[idx] += eps;
    let mut minus = inputs.clone();
    minus[0].data_mut()[idx] -= eps;
    let fp = sum(&reg.call(fwd, &plus.iter().collect::<Vec<_>>())?[0]);
    let fm = sum(&reg.call(fwd, &minus.iter().collect::<Vec<_>>())?[0]);
    let fd = ((fp - fm) / (2.0 * eps as f64)) as f32;
    let ad = grads[0].data()[idx];
    println!("fd check: finite-diff {fd:.5} vs adjoint {ad:.5} (|Δ| {:.2e})", (fd - ad).abs());
    assert!((fd - ad).abs() < 1e-2 * (1.0 + ad.abs()));
    println!("quickstart OK");
    Ok(())
}
