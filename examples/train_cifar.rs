//! End-to-end training driver (the EXPERIMENTS.md validation run): train an
//! ODE-ResNet on synthetic CIFAR-10 for a few hundred steps through the
//! `anode::api` façade and log the loss curve. All three layers compose
//! here: Pallas conv kernels (L1) inside AOT-lowered JAX ODE blocks (L2)
//! driven by the Rust Engine/Session checkpointing stack (L3).
//!
//!     make artifacts && cargo run --release --example train_cifar -- \
//!         --steps 300 --method anode
//!
//! Options: --arch resnet|sqnxt --solver euler|rk2 --method anode|node|otd|
//!          anode-revolve<m> --steps N --classes 10|100 --csv PATH

use anode::api::open_artifacts;
use anode::harness::{train_figure, TrainFigOptions};
use anode::memory::human_bytes;
use anode::metrics::{format_table, write_csv};
use anode::models::{Arch, GradMethod, Solver};
use anode::util::cli::Args;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::from_env();
    let reg = open_artifacts(args.get_or("artifacts", "artifacts"))?;
    let opts = TrainFigOptions {
        arch: Arch::parse(&args.get_or("arch", "resnet")).ok_or("bad --arch")?,
        solver: Solver::parse(&args.get_or("solver", "euler")).ok_or("bad --solver")?,
        method: GradMethod::parse(&args.get_or("method", "anode")).ok_or("bad --method")?,
        num_classes: args.get_parse_or("classes", 10),
        train_size: args.get_parse_or("train-size", 2048),
        test_size: args.get_parse_or("test-size", 512),
        steps: args.get_parse_or("steps", 300),
        eval_every: args.get_parse_or("eval-every", 25),
        lr: args.get_parse_or("lr", 0.02),
        seed: args.get_parse_or("seed", 0),
        verbose: true,
        workers: args.get_parse_or("workers", 1),
        grad_accum: args.get_parse_or("grad-accum", 1),
        grad_workers: args.get_parse_or("grad-workers", 1),
        devices: args.get_parse_or("devices", 1),
    };
    let csv = args.get("csv").map(|s| s.to_string());
    args.warn_unknown();
    println!(
        "training {} / {} / {} on synthetic CIFAR-{} ({} examples, {} steps)",
        opts.arch.name(),
        opts.solver.name(),
        opts.method.name(),
        opts.num_classes,
        opts.train_size,
        opts.steps
    );
    let run = train_figure(&reg, &opts)?;
    println!("\n{}", format_table(std::slice::from_ref(&run.curve)));
    println!(
        "diverged={} wall={:.1}s sec/step={:.3} peak_activation={}",
        run.diverged,
        run.wall_seconds,
        run.sec_per_step,
        human_bytes(run.peak_activation_bytes)
    );
    if let Some(csv) = csv {
        write_csv(std::path::Path::new(&csv), &[run.curve])?;
        println!("curve written to {csv}");
    }
    Ok(())
}
