//! Figs. 1 & 7 demo: push an MNIST-like digit through a random-Gaussian
//! conv residual block, then try to reconstruct it by solving the forward
//! ODE backwards (the neural-ODE [8] trick) — and watch it fail, for both
//! fixed-step Euler and adaptive RK45, across activation functions.
//!
//!     cargo run --release --example reversibility -- --seed 3 --std 0.4

use anode::harness::{fig1_reversibility, format_fig1};
use anode::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let seed = args.get_parse_or("seed", 3u64);
    let std = args.get_parse_or("std", 3.0f32);
    let nt = args.get_parse_or("nt", 8usize);

    println!("Fig. 1 / Fig. 7 — reversing a 1-conv residual block (std={std}, euler nt={nt})\n");
    let rows = fig1_reversibility(seed, std, nt);
    println!("{}", format_fig1(&rows));
    println!(
        "ρ = ‖φ(φ(z0,1),-1) − z0‖/‖z0‖ (Eq. 6). O(1) values mean the\n\
         reconstruction is 'completely different than the original image'\n\
         (paper, Fig. 1) — the gradients [8] computes from it are garbage."
    );

    // Contrast: a small-Lipschitz block IS reversible (§III theory).
    let tame = fig1_reversibility(seed, 0.02, 64);
    let min_rho = tame.iter().map(|r| r.rho).fold(f32::INFINITY, f32::min);
    println!("\ncontrast: with std=0.02 (small Lipschitz constant) min ρ = {min_rho:.2e} —");
    println!("reversibility holds exactly when §III's theory says it should.");
}
