use anode::runtime::XlaRuntime;
use anode::tensor::Tensor;
use std::time::Instant;
fn main() {
    let rt = XlaRuntime::cpu().unwrap();
    for (path, nin) in [("/tmp/blk_ref_vjp.hlo.txt", 6usize), ("/tmp/blk_flat_fwd.hlo.txt", 5), ("/tmp/blk_grid_fwd.hlo.txt", 5)] {
        let exe = rt.compile_hlo_text(path, std::path::Path::new(path)).unwrap();
        let shapes: Vec<Vec<usize>> = match nin {
            6 => vec![vec![32,32,32,16], vec![3,3,16,16], vec![16], vec![3,3,16,16], vec![16], vec![32,32,32,16]],
            _ => vec![vec![32,32,32,16], vec![3,3,16,16], vec![16], vec![3,3,16,16], vec![16]],
        };
        let inputs: Vec<Tensor> = shapes.iter().map(|s| Tensor::full(s, 0.1)).collect();
        let refs: Vec<&Tensor> = inputs.iter().collect();
        exe.call(&refs).unwrap();
        let t0 = Instant::now();
        for _ in 0..3 { exe.call(&refs).unwrap(); }
        println!("{:<30} warm={:?}", path, t0.elapsed()/3);
    }
}
