//! §IV demo: the Optimize-Then-Discretize adjoint (Eq. 10) is inconsistent
//! with the discrete forward pass — its gradient error is O(dt) — while the
//! neural-ODE [8] gradient carries an O(1) reconstruction error that no dt
//! refinement fixes. The ANODE (DTO) gradient matches finite differences
//! at every dt.
//!
//!     make artifacts && cargo run --release --example gradient_consistency

use anode::api::open_artifacts;
use anode::harness::{format_gradcheck, gradient_consistency};
use anode::util::cli::Args;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::from_env();
    let reg = open_artifacts(args.get_or("artifacts", "artifacts"))?;
    let seed = args.get_parse_or("seed", 5);
    args.warn_unknown();
    let rows = gradient_consistency(&reg, seed)?;
    println!("§IV — gradient consistency on the tiny ODE block (Euler, dt = 1/Nt)\n");
    println!("{}", format_gradcheck(&rows));

    // Fit the OTD error slope: err ≈ C · dt^p  =>  p ≈ 1 (Eq. 9 vs Eq. 10).
    let (mut sx, mut sy, mut sxx, mut sxy) = (0.0f64, 0.0, 0.0, 0.0);
    for r in &rows {
        let x = (r.dt as f64).ln();
        let y = (r.otd_rel_err as f64).ln();
        sx += x;
        sy += y;
        sxx += x * x;
        sxy += x * y;
    }
    let n = rows.len() as f64;
    let slope = (n * sxy - sx * sy) / (n * sxx - sx * sx);
    println!("fitted OTD error order in dt: p ≈ {slope:.2} (paper: O(dt) ⇒ p ≈ 1)");
    println!(
        "[8] error at finest dt: {:.3} (does not vanish — reconstruction instability)",
        rows.last().unwrap().node_rel_err
    );
    Ok(())
}
